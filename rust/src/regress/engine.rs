//! The comparison engine: re-run every baseline cell and flag
//! direction-aware regressions.
//!
//! Each baseline row is reconstructed as an explicit per-task
//! [`RunConfig`] — point rows via [`executor::derive_cfg`] (the same
//! derivation `gvbench run` used to produce them), sweep rows via
//! [`sweep::cell_cfg`] + [`executor::derive_cfg`] (the same quota→mem/SM
//! mapping, the same node topology and the same
//! `task_seed(topology_seed(scenario_seed(seed, tenants, quota), gpus,
//! link), system, metric)` composition `run_sweep` used) — and the whole
//! list shards through [`executor::execute_prepared_indexed`] on
//! `cfg.jobs` workers. PR-3-era sweep rows carry no topology coordinate
//! and re-run through [`sweep::legacy_cell_cfg`]: the default node
//! ([`sweep::DEFAULT_GPU_COUNT`] GPUs over [`sweep::DEFAULT_LINK`])
//! *and* the scenario-layer seed derivation (no `topology_seed` fold) —
//! exactly what their producing sweep hardcoded, so genuinely old
//! baselines stay bit-identical too. Seed parity makes an unchanged
//! tree compare clean against its own fresh baseline at any job count.

use std::sync::Arc;

use crate::anyhow::{bail, Result};
use crate::cluster;
use crate::coordinator::executor::{self, Backend, ExecutionStats, Observer, Task, TaskDone};
use crate::coordinator::sweep;
use crate::metrics::registry;
use crate::dynsim::{self, ScenarioSpec, TRACE_SCENARIO};
use crate::metrics::{taxonomy, Direction, RunConfig};
use crate::util::rng::{cluster_seed, dynamics_seed, task_seed};

use super::baseline::{
    cell_label, cluster_label, dyn_label, Baseline, BaselineSchema, CellCoord, ClusterCoord,
    DynCoord,
};

/// Percent by which `cur` is worse than `base` in the metric's own
/// direction (positive = regressed; 0 = unchanged or improved).
///
/// Baseline CSVs record 6 decimal places; a move inside that recording
/// resolution is rounding noise, not a regression (and would otherwise
/// read as an infinite relative move when a tiny value rounded to 0 in
/// the baseline).
pub fn worse_percent(direction: Direction, base: f64, cur: f64) -> f64 {
    if (cur - base).abs() <= 1.5e-6 {
        return 0.0;
    }
    match direction {
        Direction::LowerBetter => {
            if base.abs() < 1e-12 {
                if cur > 1e-12 {
                    100.0
                } else {
                    0.0
                }
            } else {
                (cur - base) / base * 100.0
            }
        }
        Direction::HigherBetter => {
            if base.abs() < 1e-12 {
                0.0
            } else {
                (base - cur) / base * 100.0
            }
        }
        Direction::Boolean => {
            if cur < base {
                100.0
            } else {
                0.0
            }
        }
    }
}

/// Comparison outcome for one re-run baseline cell.
#[derive(Clone, Debug)]
pub struct CellDelta {
    pub system: String,
    /// Sweep cell coordinate; `None` for point rows.
    pub cell: Option<CellCoord>,
    /// Dynamics cell coordinate; `Some` exactly for dynamics-schema rows.
    pub dyn_cell: Option<DynCoord>,
    /// Cluster cell coordinate; `Some` exactly for cluster-schema rows.
    pub cluster_cell: Option<ClusterCoord>,
    pub id: String,
    pub baseline: f64,
    pub current: f64,
    /// Signed change in the *bad* direction, percent (0 when unchanged or
    /// improved).
    pub worse_percent: f64,
    /// True when `worse_percent` exceeded the threshold.
    pub regressed: bool,
}

impl CellDelta {
    /// Short human label for the cell coordinate (`4t@25%` /
    /// `4t@25%/8g/nvlink` / `churn@1000ms/100ms` / `first-fit@8n/churn` /
    /// `point`).
    pub fn cell_label(&self) -> String {
        if let Some(c) = self.cluster_cell {
            return cluster_label(c);
        }
        match self.dyn_cell {
            Some(d) => dyn_label(d),
            None => cell_label(self.cell),
        }
    }
}

/// A completed regression check: every cell's delta plus run metadata.
#[derive(Clone, Debug)]
pub struct RegressOutcome {
    pub threshold_percent: f64,
    /// The run seed the re-run derived its per-task seeds from.
    pub seed: u64,
    pub schema: BaselineSchema,
    /// `feasible: false` cells present in the baseline, skipped unrun.
    pub skipped_infeasible: usize,
    /// Arrival count the baseline CSV says it was recorded at (its
    /// `# arrivals=N` header comment), when present. Cluster replays pin
    /// [`cluster::DEFAULT_ARRIVALS`]; the reporters flag a mismatch so a
    /// baseline recorded at a non-default count is self-describing.
    pub recorded_arrivals: Option<u32>,
    /// Per-cell deltas, in baseline row order.
    pub cells: Vec<CellDelta>,
    /// Executor timings of the re-run.
    pub stats: ExecutionStats,
}

impl RegressOutcome {
    /// Number of cells actually re-run and compared.
    pub fn checked(&self) -> usize {
        self.cells.len()
    }

    /// Cells that regressed beyond the threshold, in baseline order.
    pub fn regressions(&self) -> Vec<&CellDelta> {
        self.cells.iter().filter(|c| c.regressed).collect()
    }

    pub fn passed(&self) -> bool {
        self.cells.iter().all(|c| !c.regressed)
    }

    /// The baseline's recorded arrival count when it differs from the
    /// pinned cluster replay count — i.e. when the baseline can never
    /// round-trip clean and every cluster delta is suspect.
    pub fn arrivals_mismatch(&self) -> Option<u32> {
        match (self.schema, self.recorded_arrivals) {
            (BaselineSchema::Cluster, Some(n)) if n != cluster::DEFAULT_ARRIVALS => Some(n),
            _ => None,
        }
    }

    /// The worst regression (largest `worse_percent`) per system, in
    /// first-appearance order. Empty when the check passed.
    pub fn worst_per_system(&self) -> Vec<&CellDelta> {
        let mut order: Vec<&str> = Vec::new();
        let mut worst: std::collections::HashMap<&str, &CellDelta> =
            std::collections::HashMap::new();
        for c in self.cells.iter().filter(|c| c.regressed) {
            let key = c.system.as_str();
            match worst.get(key) {
                None => {
                    order.push(key);
                    worst.insert(key, c);
                }
                Some(prev) => {
                    if c.worse_percent > prev.worse_percent {
                        worst.insert(key, c);
                    }
                }
            }
        }
        order.iter().filter_map(|s| worst.get(s).copied()).collect()
    }
}

/// Re-run every feasible baseline cell — sharded across `cfg.jobs`
/// executor workers — and compare against the recorded values.
/// `cfg` supplies iterations/warmup/seed/jobs; system, scenario and
/// per-task seeds are derived per row, exactly as the producing
/// `gvbench run` / `gvbench sweep` derived them.
pub fn run_regression(
    cfg: &RunConfig,
    baseline: &Baseline,
    threshold_percent: f64,
) -> Result<RegressOutcome> {
    run_regression_on(&Backend::Scoped(cfg.jobs), cfg, baseline, threshold_percent, None)
}

/// [`run_regression`] generalized over the pool shape: the same per-row
/// reconstruction and seed derivation, executed on `exec` (scoped
/// threads or a persistent serve-daemon pool — the serve-backed gate
/// path), with an optional per-task completion observer. Bit-identical
/// to [`run_regression`] at any worker count.
pub fn run_regression_on(
    exec: &Backend<'_>,
    cfg: &RunConfig,
    baseline: &Baseline,
    threshold_percent: f64,
    observer: Option<Observer>,
) -> Result<RegressOutcome> {
    run_regression_with_trace(exec, cfg, baseline, threshold_percent, observer, None)
}

/// [`run_regression_on`] with an optional external trace timeline: rows
/// whose scenario coordinate is [`TRACE_SCENARIO`] replay `trace`
/// instead of a named preset (presets are reconstructible from their
/// name alone; a trace row needs the caller to re-supply the file it
/// was produced from, `gvbench regress --trace FILE`). Non-dynamics
/// baselines ignore `trace`.
pub fn run_regression_with_trace(
    exec: &Backend<'_>,
    cfg: &RunConfig,
    baseline: &Baseline,
    threshold_percent: f64,
    observer: Option<Observer>,
    trace: Option<&ScenarioSpec>,
) -> Result<RegressOutcome> {
    if baseline.schema == BaselineSchema::Dynamics {
        // Dynamics summaries are not registry metrics: each distinct
        // (system, scenario, geometry) coordinate replays its whole
        // timeline once, then every row compares against that run.
        return run_dynamics_regression(exec, cfg, baseline, threshold_percent, observer, trace);
    }
    if baseline.schema == BaselineSchema::Cluster {
        // Likewise for cluster summaries: one fleet replay per distinct
        // (system, policy, nodes, scenario) coordinate.
        return run_cluster_regression(exec, cfg, baseline, threshold_percent, observer);
    }
    let mut pairs: Vec<(Task, RunConfig)> = Vec::with_capacity(baseline.rows.len());
    for row in &baseline.rows {
        // Parse validated these; re-check so an engine caller constructing
        // rows by hand gets a named error rather than a panic or a
        // silently skipped row.
        let d = match taxonomy::by_id(&row.id) {
            Some(d) => d,
            None => bail!(
                "row {}: unknown metric id `{}` (system `{}`)",
                row.line,
                row.id,
                row.system
            ),
        };
        if crate::virt::by_name(&row.system).is_none() {
            bail!("row {}: unknown system `{}`", row.line, row.system);
        }
        let task_cfg = match row.cell {
            None => executor::derive_cfg(cfg, &row.system, d.id),
            Some(coord) => {
                if !sweep::cell_feasible(&row.system, coord.tenants) {
                    bail!(
                        "row {}: cell {}/{} is marked feasible but system `{}` cannot host {} tenants",
                        row.line,
                        row.system,
                        cell_label(row.cell),
                        row.system,
                        coord.tenants
                    );
                }
                // PR-3-era rows carry no topology coordinate: they were
                // produced on the then-hardcoded default node with the
                // scenario-layer seed derivation, so they re-run exactly
                // that way — bit-identical to their producing sweep.
                let cell_cfg = match coord.topo {
                    Some((gpus, link)) => sweep::cell_cfg(
                        cfg,
                        &row.system,
                        coord.tenants,
                        coord.quota_pct,
                        gpus,
                        link,
                    ),
                    None => sweep::legacy_cell_cfg(
                        cfg,
                        &row.system,
                        coord.tenants,
                        coord.quota_pct,
                    ),
                };
                executor::derive_cfg(&cell_cfg, &row.system, d.id)
            }
        };
        pairs.push((Task { system: row.system.clone(), metric_id: d.id }, task_cfg));
    }
    let tasks: Arc<Vec<Task>> = Arc::new(pairs.iter().map(|(t, _)| t.clone()).collect());
    let total = tasks.len();
    let pairs = Arc::new(pairs);
    let run = {
        let pairs = Arc::clone(&pairs);
        move |i: usize, task: &Task| {
            let result = registry::run_metric(task.metric_id, &pairs[i].1);
            if let (Some(obs), Some(r)) = (observer.as_ref(), result.as_ref()) {
                obs(TaskDone {
                    index: i,
                    total,
                    system: task.system.clone(),
                    label: task.metric_id.to_string(),
                    value: r.value,
                });
            }
            result
        }
    };
    let (slots, stats) = executor::execute_indexed_on(exec, tasks, run);
    let mut cells: Vec<CellDelta> = Vec::with_capacity(baseline.rows.len());
    for (row, slot) in baseline.rows.iter().zip(slots) {
        let result = match slot {
            Some(r) => r,
            None => bail!(
                "row {}: metric `{}` on `{}` produced no result on re-run",
                row.line,
                row.id,
                row.system
            ),
        };
        let d = taxonomy::by_id(&row.id).expect("validated above");
        let worse = worse_percent(d.direction, row.value, result.value);
        cells.push(CellDelta {
            system: row.system.clone(),
            cell: row.cell,
            dyn_cell: None,
            cluster_cell: None,
            id: row.id.clone(),
            baseline: row.value,
            current: result.value,
            worse_percent: worse,
            regressed: worse > threshold_percent,
        });
    }
    Ok(RegressOutcome {
        threshold_percent,
        seed: cfg.seed,
        schema: baseline.schema,
        skipped_infeasible: baseline.infeasible.len(),
        recorded_arrivals: baseline.recorded_arrivals,
        cells,
        stats,
    })
}

/// The dynamics-schema re-run: replay each distinct baseline timeline
/// once — sharded as (system, scenario) tasks across `cfg.jobs` executor
/// workers, with the producing run's exact seed derivation
/// (`task_seed(dynamics_seed(seed, scenario, duration, window), system,
/// scenario)`, see [`crate::dynsim::DynSpec::run_seed`]) — and compare
/// every summary row direction-aware against its recorded value.
fn run_dynamics_regression(
    exec: &Backend<'_>,
    cfg: &RunConfig,
    baseline: &Baseline,
    threshold_percent: f64,
    observer: Option<Observer>,
    trace: Option<&ScenarioSpec>,
) -> Result<RegressOutcome> {
    // Distinct (system, coordinate) timelines, first-appearance order.
    let mut groups: Vec<(String, DynCoord)> = Vec::new();
    for row in &baseline.rows {
        // Parse validated these; re-check so hand-built rows error with
        // the row named instead of panicking mid-replay.
        if taxonomy::dyn_summary_by_id(&row.id).is_none() {
            bail!(
                "row {}: unknown dynamics summary id `{}` (system `{}`)",
                row.line,
                row.id,
                row.system
            );
        }
        if crate::virt::by_name(&row.system).is_none() {
            bail!("row {}: unknown system `{}`", row.line, row.system);
        }
        let coord = match row.dyn_cell {
            Some(c) => c,
            None => bail!(
                "row {}: dynamics-schema row for {}/{} has no scenario coordinate",
                row.line,
                row.system,
                row.id
            ),
        };
        // Trace rows are only re-runnable with the producing trace in
        // hand; validate before spawning so the error names the row
        // instead of surfacing as a generic empty-replay failure.
        if coord.scenario == TRACE_SCENARIO {
            let tr = match trace {
                Some(tr) => tr,
                None => bail!(
                    "row {}: scenario `{}` needs the producing trace file re-supplied \
                     (gvbench regress --trace FILE)",
                    row.line,
                    TRACE_SCENARIO
                ),
            };
            if tr.duration_ms != coord.duration_ms || tr.window_ms != coord.window_ms {
                bail!(
                    "row {}: trace geometry {}ms/{}ms does not match the baseline row's {}ms/{}ms",
                    row.line,
                    tr.duration_ms,
                    tr.window_ms,
                    coord.duration_ms,
                    coord.window_ms
                );
            }
        }
        let key = (row.system.clone(), coord);
        if !groups.contains(&key) {
            groups.push(key);
        }
    }
    let tasks: Arc<Vec<Task>> = Arc::new(
        groups
            .iter()
            .map(|(system, coord)| Task { system: system.clone(), metric_id: coord.scenario })
            .collect(),
    );
    let total = tasks.len();
    let groups = Arc::new(groups);
    let run = {
        let groups = Arc::clone(&groups);
        let base_cfg = cfg.clone();
        let trace_spec = trace.cloned();
        move |i: usize, task: &Task| {
            let (system, coord) = &groups[i];
            let spec = if coord.scenario == TRACE_SCENARIO {
                trace_spec.clone()?
            } else {
                ScenarioSpec::preset(coord.scenario, coord.duration_ms, coord.window_ms)?
            };
            let mut run_cfg = base_cfg.clone();
            run_cfg.system = system.clone();
            run_cfg.seed = task_seed(
                dynamics_seed(base_cfg.seed, coord.scenario, coord.duration_ms, coord.window_ms),
                system,
                coord.scenario,
            );
            let replay = dynsim::engine::run_scenario(&run_cfg, &spec);
            if let Some(obs) = observer.as_ref() {
                obs(TaskDone {
                    index: i,
                    total,
                    system: task.system.clone(),
                    label: coord.scenario.to_string(),
                    value: f64::NAN,
                });
            }
            Some(replay)
        }
    };
    let (slots, stats) = executor::execute_indexed_on(exec, tasks, run);
    let mut runs = Vec::with_capacity(groups.len());
    for (slot, (system, coord)) in slots.into_iter().zip(groups.iter()) {
        match slot {
            Some(run) => runs.push(run),
            None => bail!("scenario `{}` on `{system}` produced no timeline on re-run", coord.scenario),
        }
    }
    let mut cells: Vec<CellDelta> = Vec::with_capacity(baseline.rows.len());
    for row in &baseline.rows {
        let coord = row.dyn_cell.expect("validated above");
        let idx = groups
            .iter()
            .position(|(s, c)| *s == row.system && *c == coord)
            .expect("every row belongs to a group");
        let current = match runs[idx].summary_value(&row.id) {
            Some(v) => v,
            None => bail!(
                "row {}: summary `{}` missing from the re-run of {}/{}",
                row.line,
                row.id,
                row.system,
                dyn_label(coord)
            ),
        };
        let d = taxonomy::dyn_summary_by_id(&row.id).expect("validated above");
        let worse = worse_percent(d.direction, row.value, current);
        cells.push(CellDelta {
            system: row.system.clone(),
            cell: None,
            dyn_cell: Some(coord),
            cluster_cell: None,
            id: row.id.clone(),
            baseline: row.value,
            current,
            worse_percent: worse,
            regressed: worse > threshold_percent,
        });
    }
    Ok(RegressOutcome {
        threshold_percent,
        seed: cfg.seed,
        schema: BaselineSchema::Dynamics,
        skipped_infeasible: 0,
        recorded_arrivals: baseline.recorded_arrivals,
        cells,
        stats,
    })
}

/// The cluster-schema re-run: replay each distinct baseline fleet cell
/// once — sharded as (system, coordinate) tasks across `cfg.jobs`
/// executor workers, with the producing run's exact seed derivation
/// (`task_seed(cluster_seed(seed, policy, nodes, scenario), system,
/// scenario)`, see [`crate::cluster::ClusterSpec::run_seed`]) — and
/// compare every summary row direction-aware against its recorded value.
///
/// The schema key carries no arrival count: replays always run at
/// [`cluster::DEFAULT_ARRIVALS`], which — like the run seed — is a
/// replay parameter, not a cell coordinate. Baselines produced with a
/// non-default `--arrivals` will not compare clean (`gvbench cluster`
/// warns when writing one).
fn run_cluster_regression(
    exec: &Backend<'_>,
    cfg: &RunConfig,
    baseline: &Baseline,
    threshold_percent: f64,
    observer: Option<Observer>,
) -> Result<RegressOutcome> {
    // Distinct (system, coordinate) fleet cells, first-appearance order.
    let mut groups: Vec<(String, ClusterCoord)> = Vec::new();
    for row in &baseline.rows {
        // Parse validated these; re-check so hand-built rows error with
        // the row named instead of panicking mid-replay.
        if taxonomy::cluster_summary_by_id(&row.id).is_none() {
            bail!(
                "row {}: unknown cluster summary id `{}` (system `{}`)",
                row.line,
                row.id,
                row.system
            );
        }
        if crate::virt::by_name(&row.system).is_none() {
            bail!("row {}: unknown system `{}`", row.line, row.system);
        }
        let coord = match row.cluster_cell {
            Some(c) => c,
            None => bail!(
                "row {}: cluster-schema row for {}/{} has no cell coordinate",
                row.line,
                row.system,
                row.id
            ),
        };
        if cluster::policy::by_name(coord.policy).is_none() {
            bail!(
                "row {}: unknown placement policy `{}` (system `{}`)",
                row.line,
                coord.policy,
                row.system
            );
        }
        let key = (row.system.clone(), coord);
        if !groups.contains(&key) {
            groups.push(key);
        }
    }
    let tasks: Arc<Vec<Task>> = Arc::new(
        groups
            .iter()
            .map(|(system, coord)| Task { system: system.clone(), metric_id: coord.scenario })
            .collect(),
    );
    let total = tasks.len();
    let groups = Arc::new(groups);
    let run = {
        let groups = Arc::clone(&groups);
        let base_cfg = cfg.clone();
        move |i: usize, task: &Task| {
            let (system, coord) = &groups[i];
            let policy = cluster::policy::by_name(coord.policy)?;
            let mut run_cfg = base_cfg.clone();
            run_cfg.system = system.clone();
            run_cfg.seed = task_seed(
                cluster_seed(base_cfg.seed, coord.policy, coord.nodes, coord.scenario),
                system,
                coord.scenario,
            );
            let replay = cluster::replay_fleet(
                &run_cfg,
                policy,
                coord.nodes,
                coord.scenario,
                cluster::DEFAULT_ARRIVALS,
            );
            if let Some(obs) = observer.as_ref() {
                obs(TaskDone {
                    index: i,
                    total,
                    system: task.system.clone(),
                    label: cluster_label(*coord),
                    value: replay.summary_value("CL-SUCCESS").unwrap_or(f64::NAN),
                });
            }
            Some(replay)
        }
    };
    let (slots, stats) = executor::execute_indexed_on(exec, tasks, run);
    let mut runs = Vec::with_capacity(groups.len());
    for (slot, (system, coord)) in slots.into_iter().zip(groups.iter()) {
        match slot {
            Some(run) => runs.push(run),
            None => bail!(
                "fleet cell `{}` on `{system}` produced no replay on re-run",
                cluster_label(*coord)
            ),
        }
    }
    let mut cells: Vec<CellDelta> = Vec::with_capacity(baseline.rows.len());
    for row in &baseline.rows {
        let coord = row.cluster_cell.expect("validated above");
        let idx = groups
            .iter()
            .position(|(s, c)| *s == row.system && *c == coord)
            .expect("every row belongs to a group");
        let current = match runs[idx].summary_value(&row.id) {
            Some(v) => v,
            None => bail!(
                "row {}: summary `{}` missing from the re-run of {}/{}",
                row.line,
                row.id,
                row.system,
                cluster_label(coord)
            ),
        };
        let d = taxonomy::cluster_summary_by_id(&row.id).expect("validated above");
        let worse = worse_percent(d.direction, row.value, current);
        cells.push(CellDelta {
            system: row.system.clone(),
            cell: None,
            dyn_cell: None,
            cluster_cell: Some(coord),
            id: row.id.clone(),
            baseline: row.value,
            current,
            worse_percent: worse,
            regressed: worse > threshold_percent,
        });
    }
    Ok(RegressOutcome {
        threshold_percent,
        seed: cfg.seed,
        schema: BaselineSchema::Cluster,
        skipped_infeasible: 0,
        recorded_arrivals: baseline.recorded_arrivals,
        cells,
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regress::baseline::BaselineRow;

    fn point_baseline(rows: Vec<BaselineRow>) -> Baseline {
        Baseline { schema: BaselineSchema::Point, rows, infeasible: Vec::new(), recorded_arrivals: None }
    }

    fn row(system: &str, id: &str, value: f64) -> BaselineRow {
        BaselineRow {
            system: system.to_string(),
            cell: None,
            dyn_cell: None,
            cluster_cell: None,
            id: id.to_string(),
            value,
            line: 2,
        }
    }

    #[test]
    fn worse_percent_is_direction_aware() {
        use Direction::*;
        // Lower-better: growth is bad, shrinkage is good.
        assert!((worse_percent(LowerBetter, 10.0, 12.0) - 20.0).abs() < 1e-9);
        assert!(worse_percent(LowerBetter, 10.0, 8.0) < 0.0);
        // Higher-better: shrinkage is bad.
        assert!((worse_percent(HigherBetter, 10.0, 8.0) - 20.0).abs() < 1e-9);
        assert!(worse_percent(HigherBetter, 10.0, 12.0) < 0.0);
        // Boolean: true -> false is a full regression.
        assert_eq!(worse_percent(Boolean, 1.0, 0.0), 100.0);
        assert_eq!(worse_percent(Boolean, 0.0, 1.0), 0.0);
        // Recording-resolution guard: a sub-microunit move is noise.
        assert_eq!(worse_percent(LowerBetter, 0.0, 1e-6), 0.0);
        assert_eq!(worse_percent(HigherBetter, 1.0, 1.0 + 1e-6), 0.0);
        // A tiny baseline that rounded to zero, now nonzero: flagged.
        assert_eq!(worse_percent(LowerBetter, 0.0, 0.5), 100.0);
    }

    #[test]
    fn detects_direction_aware_regressions() {
        // OH-009 is lower-better: hami measures ~0.055, so a 0.001
        // baseline is a large regression; a matching baseline is clean.
        let cfg = RunConfig::quick("hami");
        let b = point_baseline(vec![row("hami", "OH-009", 0.001)]);
        let out = run_regression(&cfg, &b, 10.0).unwrap();
        assert_eq!(out.checked(), 1);
        let regs = out.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].system, "hami");
        assert!(regs[0].worse_percent > 100.0);
        assert!(!out.passed());
        let b = point_baseline(vec![row("hami", "OH-009", 0.055)]);
        let out = run_regression(&cfg, &b, 10.0).unwrap();
        assert!(out.passed(), "{:?}", out.regressions());
    }

    #[test]
    fn rerun_matches_its_own_fresh_baseline_across_systems() {
        // A multi-system "baseline" produced by the executor compares
        // clean against a sharded re-run at a different job count.
        let cfg = RunConfig::quick("native");
        let tasks = vec![
            Task { system: "native".into(), metric_id: "PCIE-001" },
            Task { system: "hami".into(), metric_id: "PCIE-001" },
            Task { system: "fcsp".into(), metric_id: "BW-003" },
        ];
        let (results, _) = executor::execute(&cfg, &tasks, 1);
        let rows: Vec<BaselineRow> = results
            .iter()
            .map(|r| row(&r.system, r.id, r.value))
            .collect();
        let mut cfg8 = cfg.clone();
        cfg8.jobs = 8;
        let out = run_regression(&cfg8, &point_baseline(rows), 0.0001).unwrap();
        assert_eq!(out.checked(), 3);
        assert!(out.passed(), "{:?}", out.regressions());
    }

    #[test]
    fn hand_built_rows_with_unknown_coordinates_error_cleanly() {
        let cfg = RunConfig::quick("hami");
        let b = point_baseline(vec![row("hami", "NOPE-1", 1.0)]);
        let e = run_regression(&cfg, &b, 5.0).unwrap_err();
        assert!(format!("{e:#}").contains("NOPE-1"), "{e:#}");
        let b = point_baseline(vec![row("mps", "OH-001", 1.0)]);
        let e = run_regression(&cfg, &b, 5.0).unwrap_err();
        assert!(format!("{e:#}").contains("mps"), "{e:#}");
        // A sweep row claiming feasibility the backend cannot deliver.
        let mut r = row("mig", "OH-001", 1.0);
        r.cell = Some(CellCoord { tenants: 8, quota_pct: 50, topo: None });
        let b = Baseline {
            schema: BaselineSchema::Sweep,
            rows: vec![r],
            infeasible: Vec::new(), recorded_arrivals: None,
        };
        let e = run_regression(&cfg, &b, 5.0).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("cannot host 8 tenants"), "{msg}");
    }

    #[test]
    fn dynamics_baseline_round_trips_clean_and_detects_injection() {
        use crate::dynsim::{run_dynamics, DynSpec};
        use crate::report::dynamics::render_summary_csv;

        // Produce a small dynamics summary exactly as `gvbench dynamics
        // --summary-out` would…
        let cfg = RunConfig::quick("native");
        let spec = DynSpec {
            systems: vec!["native".into()],
            scenarios: vec!["steady"],
            duration_ms: 200,
            window_ms: 50,
            trace: None,
        };
        let surface = run_dynamics(&cfg, &spec, 1);
        let csv = render_summary_csv(&surface);
        let baseline = crate::regress::parse_baseline_csv(&csv, "native").unwrap();
        assert_eq!(baseline.schema, BaselineSchema::Dynamics);
        // …then the re-run (at a different job count) compares clean.
        let mut cfg8 = cfg.clone();
        cfg8.jobs = 8;
        let out = run_regression(&cfg8, &baseline, 0.0001).unwrap();
        assert_eq!(out.schema, BaselineSchema::Dynamics);
        assert_eq!(out.checked(), 5);
        assert!(out.passed(), "{:?}", out.regressions());
        // An injected per-summary regression is detected and named with
        // its full dynamics coordinate.
        let mut rows = baseline.rows.clone();
        let idx = rows.iter().position(|r| r.id == "DYN-THR-MEAN").unwrap();
        rows[idx].value *= 2.0; // higher-better: halving current = regression
        let perturbed = Baseline { schema: BaselineSchema::Dynamics, rows, infeasible: Vec::new(), recorded_arrivals: None };
        let out = run_regression(&cfg8, &perturbed, 5.0).unwrap();
        let regs = out.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].id, "DYN-THR-MEAN");
        assert_eq!(regs[0].cell_label(), "steady@200ms/50ms");
    }

    #[test]
    fn trace_rows_replay_with_the_trace_and_error_without() {
        use crate::dynsim::{parse_trace, run_dynamics, DynSpec};
        use crate::report::dynamics::render_summary_csv;

        let cfg = RunConfig::quick("native");
        let tr = parse_trace(
            "duration-ms 250\nwindow-ms 50\n\
             at 0 arrive 1 infer rate=30 quota=40\n\
             at 100 arrive 2 train rate=10 quota=40\n",
        )
        .unwrap();
        let spec = DynSpec {
            systems: vec!["native".into()],
            scenarios: vec![TRACE_SCENARIO],
            duration_ms: tr.duration_ms,
            window_ms: tr.window_ms,
            trace: Some(tr.clone()),
        };
        let surface = run_dynamics(&cfg, &spec, 1);
        let csv = render_summary_csv(&surface);
        let baseline = crate::regress::parse_baseline_csv(&csv, "native").unwrap();
        assert_eq!(baseline.schema, BaselineSchema::Dynamics);
        // With the producing trace re-supplied, the baseline compares
        // clean at a different job count (training trace: the 5 classic
        // summaries plus the 3 training statistics).
        let out = run_regression_with_trace(
            &Backend::Scoped(4),
            &cfg,
            &baseline,
            0.0001,
            None,
            Some(&tr),
        )
        .unwrap();
        assert_eq!(out.checked(), 8);
        assert!(out.passed(), "{:?}", out.regressions());
        // Without the trace the failure names the row and the flag to
        // re-supply it, before any timeline replays.
        let e = run_regression(&cfg, &baseline, 5.0).unwrap_err();
        assert!(format!("{e:#}").contains("--trace"), "{e:#}");
        // A geometry-mismatched trace is likewise rejected up front.
        let mut wrong = tr.clone();
        wrong.window_ms = 25;
        let e = run_regression_with_trace(
            &Backend::Scoped(1),
            &cfg,
            &baseline,
            5.0,
            None,
            Some(&wrong),
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("does not match"), "{e:#}");
    }

    #[test]
    fn hand_built_dynamics_rows_error_cleanly() {
        let cfg = RunConfig::quick("native");
        let mut r = row("hami", "DYN-RECOVERY", 1.0);
        // Dynamics id without a scenario coordinate.
        let b = Baseline {
            schema: BaselineSchema::Dynamics,
            rows: vec![r.clone()],
            infeasible: Vec::new(), recorded_arrivals: None,
        };
        let e = run_regression(&cfg, &b, 5.0).unwrap_err();
        assert!(format!("{e:#}").contains("no scenario coordinate"), "{e:#}");
        // Table-8 id under the dynamics schema.
        r.id = "OH-001".into();
        r.dyn_cell = Some(DynCoord { scenario: "steady", duration_ms: 100, window_ms: 50 });
        let b = Baseline { schema: BaselineSchema::Dynamics, rows: vec![r], infeasible: Vec::new(), recorded_arrivals: None };
        let e = run_regression(&cfg, &b, 5.0).unwrap_err();
        assert!(format!("{e:#}").contains("unknown dynamics summary id"), "{e:#}");
    }

    #[test]
    fn cluster_baseline_round_trips_clean_and_detects_injection() {
        use crate::cluster::{run_cluster, ClusterSpec, DEFAULT_ARRIVALS};
        use crate::report::cluster::render_summary_csv;

        // Produce a small cluster summary exactly as `gvbench cluster
        // --summary-out` would (regress replays pin the arrival count to
        // DEFAULT_ARRIVALS, so the surface must be produced at it too)…
        let cfg = RunConfig::quick("native");
        let spec = ClusterSpec {
            systems: vec!["native".into()],
            policies: vec!["first-fit", "frag-gradient"],
            node_counts: vec![2],
            scenarios: vec!["churn"],
            arrivals: DEFAULT_ARRIVALS,
        };
        let surface = run_cluster(&cfg, &spec, 1);
        let csv = render_summary_csv(&surface);
        let baseline = crate::regress::parse_baseline_csv(&csv, "native").unwrap();
        assert_eq!(baseline.schema, BaselineSchema::Cluster);
        // …then the re-run (at a different job count) compares clean.
        let mut cfg8 = cfg.clone();
        cfg8.jobs = 8;
        let out = run_regression(&cfg8, &baseline, 0.0001).unwrap();
        assert_eq!(out.schema, BaselineSchema::Cluster);
        assert_eq!(out.checked(), 10); // 2 cells × 5 summaries
        assert!(out.passed(), "{:?}", out.regressions());
        // An injected per-summary regression is detected and named with
        // its full (system, policy, nodes, scenario) coordinate.
        let mut rows = baseline.rows.clone();
        let idx = rows
            .iter()
            .position(|r| {
                r.id == "CL-SUCCESS" && r.cluster_cell.unwrap().policy == "first-fit"
            })
            .unwrap();
        rows[idx].value *= 2.0; // higher-better: a doubled baseline = regression
        let perturbed =
            Baseline { schema: BaselineSchema::Cluster, rows, infeasible: Vec::new(), recorded_arrivals: None };
        let out = run_regression(&cfg8, &perturbed, 5.0).unwrap();
        let regs = out.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].system, "native");
        assert_eq!(regs[0].id, "CL-SUCCESS");
        assert_eq!(regs[0].cell_label(), "first-fit@2n/churn");
    }

    #[test]
    fn hand_built_cluster_rows_error_cleanly() {
        let cfg = RunConfig::quick("native");
        let mut r = row("hami", "CL-SUCCESS", 1.0);
        // Cluster id without a cell coordinate.
        let b = Baseline {
            schema: BaselineSchema::Cluster,
            rows: vec![r.clone()],
            infeasible: Vec::new(), recorded_arrivals: None,
        };
        let e = run_regression(&cfg, &b, 5.0).unwrap_err();
        assert!(format!("{e:#}").contains("no cell coordinate"), "{e:#}");
        // Table-8 id under the cluster schema.
        r.id = "OH-001".into();
        r.cluster_cell = Some(ClusterCoord { policy: "first-fit", nodes: 2, scenario: "steady" });
        let b = Baseline { schema: BaselineSchema::Cluster, rows: vec![r], infeasible: Vec::new(), recorded_arrivals: None };
        let e = run_regression(&cfg, &b, 5.0).unwrap_err();
        assert!(format!("{e:#}").contains("unknown cluster summary id"), "{e:#}");
    }

    #[test]
    fn recorded_arrivals_mismatch_is_surfaced() {
        // A cluster baseline whose `# arrivals=N` comment differs from the
        // pinned replay count flags itself; matching or absent counts and
        // non-cluster schemas stay quiet.
        let mut out = RegressOutcome {
            threshold_percent: 5.0,
            seed: 42,
            schema: BaselineSchema::Cluster,
            skipped_infeasible: 0,
            recorded_arrivals: Some(250),
            cells: Vec::new(),
            stats: ExecutionStats::default(),
        };
        assert_eq!(out.arrivals_mismatch(), Some(250));
        out.recorded_arrivals = Some(cluster::DEFAULT_ARRIVALS);
        assert_eq!(out.arrivals_mismatch(), None);
        out.recorded_arrivals = None;
        assert_eq!(out.arrivals_mismatch(), None);
        out.schema = BaselineSchema::Point;
        out.recorded_arrivals = Some(250);
        assert_eq!(out.arrivals_mismatch(), None);
    }

    #[test]
    fn worst_per_system_picks_the_largest_regression() {
        let delta = |system: &str, id: &str, worse: f64| CellDelta {
            system: system.to_string(),
            cell: Some(CellCoord { tenants: 4, quota_pct: 25, topo: None }),
            dyn_cell: None,
            cluster_cell: None,
            id: id.to_string(),
            baseline: 1.0,
            current: 2.0,
            worse_percent: worse,
            regressed: worse > 5.0,
        };
        let out = RegressOutcome {
            threshold_percent: 5.0,
            seed: 42,
            schema: BaselineSchema::Sweep,
            skipped_infeasible: 0,
            recorded_arrivals: None,
            cells: vec![
                delta("hami", "OH-001", 12.0),
                delta("hami", "OH-002", 40.0),
                delta("fcsp", "OH-001", 8.0),
                delta("fcsp", "OH-003", 2.0), // under threshold
            ],
            stats: ExecutionStats::default(),
        };
        assert_eq!(out.regressions().len(), 3);
        let worst = out.worst_per_system();
        assert_eq!(worst.len(), 2);
        assert_eq!(worst[0].system, "hami");
        assert_eq!(worst[0].id, "OH-002");
        assert_eq!(worst[1].system, "fcsp");
        assert_eq!(worst[1].id, "OH-001");
    }
}
