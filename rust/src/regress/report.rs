//! Machine-readable regression reports: a JSON document (per-cell
//! deltas, threshold, pass/fail, executor timings) for artifact
//! pipelines, and a GitHub-flavored markdown summary (worst regressions
//! per system) that the CI gate jobs append to `$GITHUB_STEP_SUMMARY`.

use crate::report::json::{array, render_execution, Obj};

use super::engine::{CellDelta, RegressOutcome};

fn delta_obj(c: &CellDelta) -> Obj {
    let mut o = Obj::new().str("system", &c.system);
    o = match c.cell {
        Some((t, q)) => {
            o.field("tenants", t.to_string()).field("quota_pct", q.to_string())
        }
        None => o.field("tenants", "null".to_string()).field("quota_pct", "null".to_string()),
    };
    o.str("id", &c.id)
        .num("baseline", c.baseline)
        .num("current", c.current)
        .num("worse_percent", c.worse_percent)
        .bool("regressed", c.regressed)
}

/// The full JSON regression report.
pub fn render_json(outcome: &RegressOutcome, baseline_label: &str) -> String {
    let cells: Vec<String> = outcome.cells.iter().map(|c| delta_obj(c).build()).collect();
    let regressions: Vec<String> =
        outcome.regressions().iter().map(|c| delta_obj(c).build()).collect();
    Obj::new()
        .str("benchmark_version", crate::VERSION)
        .str("baseline", baseline_label)
        .str("schema", outcome.schema.key())
        .num("threshold_percent", outcome.threshold_percent)
        .field("seed", outcome.seed.to_string())
        .bool("passed", outcome.passed())
        .field("checked", outcome.checked().to_string())
        .field("regression_count", regressions.len().to_string())
        .field("skipped_infeasible", outcome.skipped_infeasible.to_string())
        .field("cells", array(cells))
        .field("regressions", array(regressions))
        .field("execution", render_execution(&outcome.stats))
        .build()
}

fn md_row(out: &mut String, c: &CellDelta) {
    out.push_str(&format!(
        "| {} | {} | {} | {:.6} | {:.6} | {:+.1}% |\n",
        c.system,
        c.cell_label(),
        c.id,
        c.baseline,
        c.current,
        c.worse_percent
    ));
}

const MD_TABLE_HEADER: &str =
    "| System | Cell | Metric | Baseline | Current | Worse by |\n|---|---|---|---:|---:|---:|\n";

/// Regressions listed in full before truncating the markdown table.
const MD_REGRESSION_CAP: usize = 20;

/// GitHub-flavored markdown summary of the check.
pub fn render_markdown(outcome: &RegressOutcome, baseline_label: &str) -> String {
    let regressions = outcome.regressions();
    let mut out = String::new();
    let status = if outcome.passed() { "✅ PASS" } else { "❌ FAIL" };
    out.push_str(&format!("## GPU-Virt-Bench regression gate — {status}\n\n"));
    out.push_str(&format!(
        "`{}` ({} baseline, seed {}): **{}** cells checked against a {:.1}% threshold, **{}** regressed, {} infeasible cell(s) skipped.\n\n",
        baseline_label,
        outcome.schema.key(),
        outcome.seed,
        outcome.checked(),
        outcome.threshold_percent,
        regressions.len(),
        outcome.skipped_infeasible
    ));
    if regressions.is_empty() {
        out.push_str("All cells within threshold.\n\n");
    } else {
        out.push_str("### Worst regression per system\n\n");
        out.push_str(MD_TABLE_HEADER);
        for c in outcome.worst_per_system() {
            md_row(&mut out, c);
        }
        out.push('\n');
        out.push_str(&format!("### All regressions ({})\n\n", regressions.len()));
        out.push_str(MD_TABLE_HEADER);
        for c in regressions.iter().take(MD_REGRESSION_CAP) {
            md_row(&mut out, c);
        }
        if regressions.len() > MD_REGRESSION_CAP {
            out.push_str(&format!(
                "\n…and {} more (see the JSON report artifact).\n",
                regressions.len() - MD_REGRESSION_CAP
            ));
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "<sub>re-ran {} tasks on {} workers in {:.2}s (busy/wall {:.2}x)</sub>\n",
        outcome.stats.tasks.len(),
        outcome.stats.jobs,
        outcome.stats.wall_ns as f64 / 1e9,
        outcome.stats.speedup_estimate()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::ExecutionStats;
    use crate::regress::baseline::BaselineSchema;

    fn delta(system: &str, cell: Option<(u32, u32)>, id: &str, worse: f64) -> CellDelta {
        CellDelta {
            system: system.to_string(),
            cell,
            id: id.to_string(),
            baseline: 10.0,
            current: 10.0 * (1.0 + worse / 100.0),
            worse_percent: worse,
            regressed: worse > 5.0,
        }
    }

    fn outcome(cells: Vec<CellDelta>) -> RegressOutcome {
        RegressOutcome {
            threshold_percent: 5.0,
            seed: 42,
            schema: BaselineSchema::Sweep,
            skipped_infeasible: 1,
            cells,
            stats: ExecutionStats::default(),
        }
    }

    #[test]
    fn json_report_carries_cells_and_verdict() {
        let out = outcome(vec![
            delta("hami", Some((4, 25)), "OH-001", 40.0),
            delta("hami", Some((1, 100)), "OH-001", 0.0),
        ]);
        let j = render_json(&out, "ci/baseline_sweep.csv");
        assert!(j.contains("\"baseline\": \"ci/baseline_sweep.csv\""), "{j}");
        assert!(j.contains("\"schema\": \"sweep\""), "{j}");
        assert!(j.contains("\"passed\": false"), "{j}");
        assert!(j.contains("\"checked\": 2"), "{j}");
        assert!(j.contains("\"regression_count\": 1"), "{j}");
        assert!(j.contains("\"skipped_infeasible\": 1"), "{j}");
        assert!(j.contains("\"tenants\": 4"), "{j}");
        assert!(j.contains("\"quota_pct\": 25"), "{j}");
        assert!(j.contains("\"worse_percent\": 40"), "{j}");
        assert!(j.contains("\"execution\""), "{j}");
    }

    #[test]
    fn json_point_rows_have_null_cells() {
        let out = outcome(vec![delta("hami", None, "OH-001", 0.0)]);
        let j = render_json(&out, "b.csv");
        assert!(j.contains("\"tenants\": null"), "{j}");
        assert!(j.contains("\"quota_pct\": null"), "{j}");
        assert!(j.contains("\"passed\": true"), "{j}");
    }

    #[test]
    fn markdown_pass_is_compact() {
        let m = render_markdown(&outcome(vec![delta("hami", None, "OH-001", 0.0)]), "b.csv");
        assert!(m.contains("✅ PASS"), "{m}");
        assert!(m.contains("All cells within threshold."), "{m}");
        assert!(m.contains("1 infeasible cell(s) skipped"), "{m}");
        assert!(!m.contains("Worst regression"), "{m}");
    }

    #[test]
    fn markdown_fail_lists_worst_per_system() {
        let out = outcome(vec![
            delta("hami", Some((4, 25)), "OH-001", 12.0),
            delta("hami", Some((8, 25)), "OH-002", 40.0),
            delta("fcsp", Some((2, 50)), "OH-001", 8.0),
        ]);
        let m = render_markdown(&out, "ci/baseline_sweep.csv");
        assert!(m.contains("❌ FAIL"), "{m}");
        assert!(m.contains("### Worst regression per system"), "{m}");
        assert!(m.contains("### All regressions (3)"), "{m}");
        assert!(m.contains("| hami | 8t@25% | OH-002 |"), "{m}");
        assert!(m.contains("| fcsp | 2t@50% | OH-001 |"), "{m}");
        // Worst-per-system section lists OH-002 (40%) for hami, not OH-001.
        let worst_idx = m.find("Worst regression per system").unwrap();
        let all_idx = m.find("All regressions").unwrap();
        assert!(!m[worst_idx..all_idx].contains("4t@25%"), "{m}");
    }

    #[test]
    fn markdown_caps_the_regression_table() {
        let cells: Vec<CellDelta> = (0..30)
            .map(|i| delta("hami", Some((4, 25)), ["OH-001", "OH-002", "OH-003"][i % 3], 20.0))
            .collect();
        // Distinct ids per row aren't needed; the cap is about row count.
        let m = render_markdown(&outcome(cells), "b.csv");
        assert!(m.contains("…and 10 more"), "{m}");
    }
}
