//! Machine-readable regression reports: a JSON document (per-cell
//! deltas, threshold, pass/fail, executor timings, a per-link-kind
//! breakdown) for artifact pipelines, and a GitHub-flavored markdown
//! summary (worst regressions per system, regressions grouped by link
//! kind) that the CI gate jobs append to `$GITHUB_STEP_SUMMARY`.

use crate::report::json::{array, render_execution, Obj};

use super::engine::{CellDelta, RegressOutcome};

fn delta_obj(c: &CellDelta) -> Obj {
    let null = || "null".to_string();
    let mut o = Obj::new().str("system", &c.system);
    o = match c.cell {
        Some(coord) => {
            let o2 = o
                .field("tenants", coord.tenants.to_string())
                .field("quota_pct", coord.quota_pct.to_string());
            match coord.topo {
                Some((gpus, link)) => {
                    o2.field("gpu_count", gpus.to_string()).str("link", link.key())
                }
                None => o2.field("gpu_count", null()).field("link", null()),
            }
        }
        None => o
            .field("tenants", null())
            .field("quota_pct", null())
            .field("gpu_count", null())
            .field("link", null()),
    };
    // Dynamics rows carry the scenario coordinate instead of a sweep
    // cell; cluster rows share the scenario axis and add the fleet
    // coordinate (policy, nodes).
    o = match c.dyn_cell {
        Some(d) => o
            .str("scenario", d.scenario)
            .field("duration_ms", d.duration_ms.to_string())
            .field("window_ms", d.window_ms.to_string()),
        None => match c.cluster_cell {
            Some(cl) => o.str("scenario", cl.scenario),
            None => o.field("scenario", null()),
        },
    };
    o = match c.cluster_cell {
        Some(cl) => o.str("policy", cl.policy).field("nodes", cl.nodes.to_string()),
        None => o.field("policy", null()).field("nodes", null()),
    };
    o.str("id", &c.id)
        .num("baseline", c.baseline)
        .num("current", c.current)
        .num("worse_percent", c.worse_percent)
        .bool("regressed", c.regressed)
}

/// Grouping label for the per-link-kind breakdown: the cell's link kind
/// for extended sweep rows, `default-node` for PR-3-era rows (which
/// re-ran on the default 4-GPU PCIe node), `dynamics` for
/// scenario-timeline rows, `cluster` for fleet-placement rows and
/// `point` for point rows.
fn link_group(c: &CellDelta) -> &'static str {
    if c.cluster_cell.is_some() {
        return "cluster";
    }
    if c.dyn_cell.is_some() {
        return "dynamics";
    }
    match c.cell {
        Some(coord) => match coord.topo {
            Some((_, link)) => link.key(),
            None => "default-node",
        },
        None => "point",
    }
}

/// Per-link-kind delta summary: `(label, checked, regressed, worst)`,
/// in first-appearance order over the outcome's cells.
fn link_breakdown(outcome: &RegressOutcome) -> Vec<(&'static str, usize, usize, Option<&CellDelta>)> {
    let mut order: Vec<&'static str> = Vec::new();
    let mut stats: std::collections::HashMap<&'static str, (usize, usize, Option<&CellDelta>)> =
        std::collections::HashMap::new();
    for c in &outcome.cells {
        let key = link_group(c);
        if !stats.contains_key(key) {
            order.push(key);
            stats.insert(key, (0, 0, None));
        }
        let entry = stats.get_mut(key).expect("inserted above");
        entry.0 += 1;
        if c.regressed {
            entry.1 += 1;
            let replace = match entry.2 {
                None => true,
                Some(prev) => c.worse_percent > prev.worse_percent,
            };
            if replace {
                entry.2 = Some(c);
            }
        }
    }
    order
        .into_iter()
        .map(|k| {
            let (checked, regressed, worst) = stats[k];
            (k, checked, regressed, worst)
        })
        .collect()
}

/// The full JSON regression report.
pub fn render_json(outcome: &RegressOutcome, baseline_label: &str) -> String {
    let cells: Vec<String> = outcome.cells.iter().map(|c| delta_obj(c).build()).collect();
    let regressions: Vec<String> =
        outcome.regressions().iter().map(|c| delta_obj(c).build()).collect();
    let by_link: Vec<String> = link_breakdown(outcome)
        .into_iter()
        .map(|(label, checked, regressed, worst)| {
            let mut o = Obj::new()
                .str("link", label)
                .field("checked", checked.to_string())
                .field("regressed", regressed.to_string());
            if let Some(w) = worst {
                o = o.field("worst", delta_obj(w).build());
            }
            o.build()
        })
        .collect();
    Obj::new()
        .str("benchmark_version", crate::VERSION)
        .str("baseline", baseline_label)
        .str("schema", outcome.schema.key())
        .num("threshold_percent", outcome.threshold_percent)
        .field("seed", outcome.seed.to_string())
        .bool("passed", outcome.passed())
        .field("checked", outcome.checked().to_string())
        .field("regression_count", regressions.len().to_string())
        .field("skipped_infeasible", outcome.skipped_infeasible.to_string())
        .field(
            "recorded_arrivals",
            match outcome.recorded_arrivals {
                Some(n) => n.to_string(),
                None => "null".to_string(),
            },
        )
        .field("cells", array(cells))
        .field("regressions", array(regressions))
        .field("by_link", array(by_link))
        .field("execution", render_execution(&outcome.stats))
        .build()
}

fn md_row(out: &mut String, c: &CellDelta) {
    out.push_str(&format!(
        "| {} | {} | {} | {:.6} | {:.6} | {:+.1}% |\n",
        c.system,
        c.cell_label(),
        c.id,
        c.baseline,
        c.current,
        c.worse_percent
    ));
}

const MD_TABLE_HEADER: &str =
    "| System | Cell | Metric | Baseline | Current | Worse by |\n|---|---|---|---:|---:|---:|\n";

/// Regressions listed in full before truncating the markdown table.
const MD_REGRESSION_CAP: usize = 20;

/// GitHub-flavored markdown summary of the check.
pub fn render_markdown(outcome: &RegressOutcome, baseline_label: &str) -> String {
    let regressions = outcome.regressions();
    let mut out = String::new();
    let status = if outcome.passed() { "✅ PASS" } else { "❌ FAIL" };
    out.push_str(&format!("## GPU-Virt-Bench regression gate — {status}\n\n"));
    out.push_str(&format!(
        "`{}` ({} baseline, seed {}): **{}** cells checked against a {:.1}% threshold, **{}** regressed, {} infeasible cell(s) skipped.\n\n",
        baseline_label,
        outcome.schema.key(),
        outcome.seed,
        outcome.checked(),
        outcome.threshold_percent,
        regressions.len(),
        outcome.skipped_infeasible
    ));
    if let Some(n) = outcome.arrivals_mismatch() {
        out.push_str(&format!(
            "> ⚠️ The baseline records **{n} arrivals** per replay but the gate re-ran it at \
             the default {} — deltas compare different workloads. Re-arm the baseline at the \
             default arrival count.\n\n",
            crate::cluster::DEFAULT_ARRIVALS
        ));
    }
    if regressions.is_empty() {
        out.push_str("All cells within threshold.\n\n");
    } else {
        out.push_str("### Worst regression per system\n\n");
        out.push_str(MD_TABLE_HEADER);
        for c in outcome.worst_per_system() {
            md_row(&mut out, c);
        }
        out.push('\n');
        out.push_str(&format!("### All regressions ({})\n\n", regressions.len()));
        out.push_str(MD_TABLE_HEADER);
        for c in regressions.iter().take(MD_REGRESSION_CAP) {
            md_row(&mut out, c);
        }
        if regressions.len() > MD_REGRESSION_CAP {
            out.push_str(&format!(
                "\n…and {} more (see the JSON report artifact).\n",
                regressions.len() - MD_REGRESSION_CAP
            ));
        }
        out.push('\n');
        // Per-link breakdown — only worth a section when the baseline
        // spans more than one link group.
        let breakdown = link_breakdown(outcome);
        if breakdown.len() > 1 {
            out.push_str("### Regressions by link kind\n\n");
            out.push_str("| Link | Checked | Regressed | Worst cell | Worse by |\n|---|---:|---:|---|---:|\n");
            for (label, checked, regressed, worst) in breakdown {
                match worst {
                    Some(w) => out.push_str(&format!(
                        "| {} | {} | {} | {} {} {} | {:+.1}% |\n",
                        label,
                        checked,
                        regressed,
                        w.system,
                        w.cell_label(),
                        w.id,
                        w.worse_percent
                    )),
                    None => out.push_str(&format!(
                        "| {label} | {checked} | {regressed} | — | — |\n"
                    )),
                }
            }
            out.push('\n');
        }
    }
    out.push_str(&format!(
        "<sub>re-ran {} tasks on {} workers in {:.2}s (busy/wall {:.2}x)</sub>\n",
        outcome.stats.tasks.len(),
        outcome.stats.jobs,
        outcome.stats.wall_ns as f64 / 1e9,
        outcome.stats.speedup_estimate()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::ExecutionStats;
    use crate::regress::baseline::{BaselineSchema, CellCoord};
    use crate::simgpu::nvlink::LinkKind;

    fn delta(system: &str, cell: Option<(u32, u32)>, id: &str, worse: f64) -> CellDelta {
        CellDelta {
            system: system.to_string(),
            cell: cell.map(|(tenants, quota_pct)| CellCoord { tenants, quota_pct, topo: None }),
            dyn_cell: None,
            cluster_cell: None,
            id: id.to_string(),
            baseline: 10.0,
            current: 10.0 * (1.0 + worse / 100.0),
            worse_percent: worse,
            regressed: worse > 5.0,
        }
    }

    fn delta_on(
        system: &str,
        cell: (u32, u32),
        topo: (u32, LinkKind),
        id: &str,
        worse: f64,
    ) -> CellDelta {
        let mut d = delta(system, Some(cell), id, worse);
        d.cell = Some(CellCoord { tenants: cell.0, quota_pct: cell.1, topo: Some(topo) });
        d
    }

    fn outcome(cells: Vec<CellDelta>) -> RegressOutcome {
        RegressOutcome {
            threshold_percent: 5.0,
            seed: 42,
            schema: BaselineSchema::Sweep,
            skipped_infeasible: 1,
            cells,
            stats: ExecutionStats::default(),
            recorded_arrivals: None,
        }
    }

    #[test]
    fn json_report_carries_cells_and_verdict() {
        let out = outcome(vec![
            delta("hami", Some((4, 25)), "OH-001", 40.0),
            delta("hami", Some((1, 100)), "OH-001", 0.0),
        ]);
        let j = render_json(&out, "ci/baseline_sweep.csv");
        assert!(j.contains("\"baseline\": \"ci/baseline_sweep.csv\""), "{j}");
        assert!(j.contains("\"schema\": \"sweep\""), "{j}");
        assert!(j.contains("\"passed\": false"), "{j}");
        assert!(j.contains("\"checked\": 2"), "{j}");
        assert!(j.contains("\"regression_count\": 1"), "{j}");
        assert!(j.contains("\"skipped_infeasible\": 1"), "{j}");
        assert!(j.contains("\"tenants\": 4"), "{j}");
        assert!(j.contains("\"quota_pct\": 25"), "{j}");
        assert!(j.contains("\"worse_percent\": 40"), "{j}");
        assert!(j.contains("\"execution\""), "{j}");
    }

    #[test]
    fn json_point_rows_have_null_cells() {
        let out = outcome(vec![delta("hami", None, "OH-001", 0.0)]);
        let j = render_json(&out, "b.csv");
        assert!(j.contains("\"tenants\": null"), "{j}");
        assert!(j.contains("\"quota_pct\": null"), "{j}");
        assert!(j.contains("\"gpu_count\": null"), "{j}");
        assert!(j.contains("\"link\": null"), "{j}");
        assert!(j.contains("\"passed\": true"), "{j}");
    }

    #[test]
    fn json_extended_rows_carry_topology_and_by_link_groups() {
        let out = outcome(vec![
            delta_on("hami", (4, 25), (8, LinkKind::NvLink), "NCCL-001", 40.0),
            delta_on("hami", (4, 25), (8, LinkKind::Pcie), "NCCL-001", 0.0),
            delta("hami", Some((4, 25)), "OH-001", 0.0),
        ]);
        let j = render_json(&out, "b.csv");
        assert!(j.contains("\"gpu_count\": 8"), "{j}");
        assert!(j.contains("\"link\": \"nvlink\""), "{j}");
        assert!(j.contains("\"by_link\""), "{j}");
        let idx = j.find("\"by_link\"").unwrap();
        // Three groups: nvlink, pcie, default-node (the PR-3-era row).
        assert!(j[idx..].contains("\"link\": \"nvlink\""), "{j}");
        assert!(j[idx..].contains("\"link\": \"pcie\""), "{j}");
        assert!(j[idx..].contains("\"link\": \"default-node\""), "{j}");
        assert!(j[idx..].contains("\"worst\""), "{j}");
    }

    #[test]
    fn dynamics_rows_carry_scenario_coordinates() {
        use crate::regress::baseline::DynCoord;
        let mut d = delta("hami", None, "DYN-P99-STEADY", 22.0);
        d.dyn_cell = Some(DynCoord { scenario: "churn", duration_ms: 1000, window_ms: 100 });
        let mut out = outcome(vec![d, delta("hami", Some((4, 25)), "OH-001", 0.0)]);
        out.schema = BaselineSchema::Dynamics;
        let j = render_json(&out, "dyn_summary.csv");
        assert!(j.contains("\"schema\": \"dynamics\""), "{j}");
        assert!(j.contains("\"scenario\": \"churn\""), "{j}");
        assert!(j.contains("\"duration_ms\": 1000"), "{j}");
        assert!(j.contains("\"window_ms\": 100"), "{j}");
        assert!(j.contains("\"scenario\": null"), "{j}");
        // The by-link breakdown groups timeline rows under `dynamics`.
        let idx = j.find("\"by_link\"").unwrap();
        assert!(j[idx..].contains("\"link\": \"dynamics\""), "{j}");
        let m = render_markdown(&out, "dyn_summary.csv");
        assert!(m.contains("| hami | churn@1000ms/100ms | DYN-P99-STEADY |"), "{m}");
    }

    #[test]
    fn cluster_rows_carry_fleet_coordinates() {
        use crate::regress::baseline::ClusterCoord;
        let mut d = delta("hami", None, "CL-SUCCESS", 22.0);
        d.cluster_cell = Some(ClusterCoord { policy: "frag-gradient", nodes: 8, scenario: "churn" });
        let mut out = outcome(vec![d, delta("hami", Some((4, 25)), "OH-001", 0.0)]);
        out.schema = BaselineSchema::Cluster;
        let j = render_json(&out, "cluster_summary.csv");
        assert!(j.contains("\"schema\": \"cluster\""), "{j}");
        assert!(j.contains("\"policy\": \"frag-gradient\""), "{j}");
        assert!(j.contains("\"nodes\": 8"), "{j}");
        assert!(j.contains("\"scenario\": \"churn\""), "{j}");
        assert!(j.contains("\"policy\": null"), "{j}");
        assert!(j.contains("\"nodes\": null"), "{j}");
        // The by-link breakdown groups fleet rows under `cluster`.
        let idx = j.find("\"by_link\"").unwrap();
        assert!(j[idx..].contains("\"link\": \"cluster\""), "{j}");
        let m = render_markdown(&out, "cluster_summary.csv");
        assert!(m.contains("| hami | frag-gradient@8n/churn | CL-SUCCESS |"), "{m}");
    }

    #[test]
    fn arrivals_provenance_is_reported_and_mismatches_warn() {
        use crate::regress::baseline::ClusterCoord;
        let mut d = delta("hami", None, "CL-SUCCESS", 0.0);
        d.cluster_cell = Some(ClusterCoord { policy: "first-fit", nodes: 2, scenario: "churn" });
        let mut out = outcome(vec![d]);
        out.schema = BaselineSchema::Cluster;
        // Without a recorded count the JSON field is null and the
        // markdown stays silent.
        let j = render_json(&out, "cluster_summary.csv");
        assert!(j.contains("\"recorded_arrivals\": null"), "{j}");
        assert!(!render_markdown(&out, "b.csv").contains("⚠️"));
        // A matching recorded count is surfaced without a warning…
        out.recorded_arrivals = Some(crate::cluster::DEFAULT_ARRIVALS);
        let j = render_json(&out, "b.csv");
        assert!(
            j.contains(&format!("\"recorded_arrivals\": {}", crate::cluster::DEFAULT_ARRIVALS)),
            "{j}"
        );
        assert!(!render_markdown(&out, "b.csv").contains("⚠️"));
        // …while a non-default one warns in the markdown.
        out.recorded_arrivals = Some(5);
        let j = render_json(&out, "b.csv");
        assert!(j.contains("\"recorded_arrivals\": 5"), "{j}");
        let m = render_markdown(&out, "b.csv");
        assert!(m.contains("**5 arrivals**"), "{m}");
        assert!(m.contains("Re-arm the baseline"), "{m}");
    }

    #[test]
    fn markdown_pass_is_compact() {
        let m = render_markdown(&outcome(vec![delta("hami", None, "OH-001", 0.0)]), "b.csv");
        assert!(m.contains("✅ PASS"), "{m}");
        assert!(m.contains("All cells within threshold."), "{m}");
        assert!(m.contains("1 infeasible cell(s) skipped"), "{m}");
        assert!(!m.contains("Worst regression"), "{m}");
    }

    #[test]
    fn markdown_fail_lists_worst_per_system() {
        let out = outcome(vec![
            delta("hami", Some((4, 25)), "OH-001", 12.0),
            delta("hami", Some((8, 25)), "OH-002", 40.0),
            delta("fcsp", Some((2, 50)), "OH-001", 8.0),
        ]);
        let m = render_markdown(&out, "ci/baseline_sweep.csv");
        assert!(m.contains("❌ FAIL"), "{m}");
        assert!(m.contains("### Worst regression per system"), "{m}");
        assert!(m.contains("### All regressions (3)"), "{m}");
        assert!(m.contains("| hami | 8t@25% | OH-002 |"), "{m}");
        assert!(m.contains("| fcsp | 2t@50% | OH-001 |"), "{m}");
        // Worst-per-system section lists OH-002 (40%) for hami, not OH-001.
        let worst_idx = m.find("Worst regression per system").unwrap();
        let all_idx = m.find("All regressions").unwrap();
        assert!(!m[worst_idx..all_idx].contains("4t@25%"), "{m}");
    }

    #[test]
    fn markdown_groups_regressions_by_link_kind() {
        let out = outcome(vec![
            delta_on("hami", (2, 50), (8, LinkKind::NvLink), "NCCL-001", 40.0),
            delta_on("hami", (2, 50), (8, LinkKind::Pcie), "NCCL-001", 12.0),
            delta_on("hami", (2, 50), (4, LinkKind::Pcie), "NCCL-002", 18.0),
        ]);
        let m = render_markdown(&out, "b.csv");
        assert!(m.contains("### Regressions by link kind"), "{m}");
        // The worst pcie regression is the 18% one on the 4-GPU node.
        assert!(m.contains("| pcie | 2 | 2 | hami 2t@50%/4g/pcie NCCL-002 | +18.0% |"), "{m}");
        assert!(m.contains("| nvlink | 1 | 1 | hami 2t@50%/8g/nvlink NCCL-001 | +40.0% |"), "{m}");
        // A single-group outcome keeps the summary compact.
        let single = outcome(vec![delta("hami", Some((4, 25)), "OH-001", 12.0)]);
        let m = render_markdown(&single, "b.csv");
        assert!(!m.contains("by link kind"), "{m}");
    }

    #[test]
    fn markdown_caps_the_regression_table() {
        let cells: Vec<CellDelta> = (0..30)
            .map(|i| delta("hami", Some((4, 25)), ["OH-001", "OH-002", "OH-003"][i % 3], 20.0))
            .collect();
        // Distinct ids per row aren't needed; the cap is about row count.
        let m = render_markdown(&outcome(cells), "b.csv");
        assert!(m.contains("…and 10 more"), "{m}");
    }
}
