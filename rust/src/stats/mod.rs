//! Statistical reduction of benchmark samples (the paper's §4.4).
//!
//! Every metric collects `iterations` samples after `warmup` discarded
//! runs, then reduces to mean, standard deviation, median, P95, P99 and the
//! coefficient of variation. Jain's fairness index (paper eq. 10) lives
//! here too since three metric categories use it.

/// Summary statistics over a sample vector (paper §4.4).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
    pub p99: f64,
    /// Coefficient of variation `σ/µ` (0 when mean is 0).
    pub cv: f64,
}

impl Summary {
    /// Reduce a sample vector. Returns a zeroed summary for empty input.
    pub fn from_samples(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        // Population variance: the samples *are* the run being reported.
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        let stddev = var.sqrt();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        Summary {
            count: samples.len(),
            mean,
            stddev,
            min: sorted[0],
            max: *sorted.last().unwrap(),
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            cv: if mean.abs() > f64::EPSILON { stddev / mean } else { 0.0 },
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice (inclusive method,
/// matching numpy's default).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Percentile of an unsorted slice.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
    percentile_sorted(&sorted, p)
}

/// Jain's fairness index (paper eq. 10):
/// `J(x) = (Σxᵢ)² / (n · Σxᵢ²)`. Returns 1.0 for empty/singleton input and
/// for all-zero throughputs (degenerate but "fair").
pub fn jain_fairness(xs: &[f64]) -> f64 {
    if xs.len() <= 1 {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sum_sq: f64 = xs.iter().map(|x| x * x).sum();
    if sum_sq <= f64::EPSILON {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sum_sq)
}

/// Coefficient of variation of a sample vector (paper eq. 9).
pub fn coefficient_of_variation(xs: &[f64]) -> f64 {
    Summary::from_samples(xs).cv
}

/// Sample collector with warmup discard, mirroring the paper's
/// "N iterations (default 100) with warmup runs (default 10)".
#[derive(Clone, Debug)]
pub struct Collector {
    warmup_remaining: usize,
    samples: Vec<f64>,
}

impl Collector {
    pub fn new(warmup: usize, capacity: usize) -> Collector {
        Collector { warmup_remaining: warmup, samples: Vec::with_capacity(capacity) }
    }

    /// Record one measurement; the first `warmup` records are discarded.
    pub fn record(&mut self, value: f64) {
        if self.warmup_remaining > 0 {
            self.warmup_remaining -= 1;
        } else {
            self.samples.push(value);
        }
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn summary(&self) -> Summary {
        Summary::from_samples(&self.samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 5.0).abs() < 1e-12);
        // population stddev of 1..5 = sqrt(2)
        assert!((s.stddev - 2.0_f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_zeroed() {
        assert_eq!(Summary::from_samples(&[]), Summary::default());
    }

    #[test]
    fn percentiles_interpolate() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile(&v, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile(&v, 100.0) - 40.0).abs() < 1e-12);
        assert!((percentile(&v, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn p99_close_to_max_for_uniform() {
        let v: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let p99 = percentile(&v, 99.0);
        assert!(p99 > 985.0 && p99 < 995.0, "p99={p99}");
    }

    #[test]
    fn jain_perfect_fairness() {
        assert!((jain_fairness(&[5.0, 5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn jain_worst_case_one_over_n() {
        // One tenant gets everything: J = 1/n.
        let j = jain_fairness(&[10.0, 0.0, 0.0, 0.0]);
        assert!((j - 0.25).abs() < 1e-12, "j={j}");
    }

    #[test]
    fn jain_degenerate_inputs() {
        assert_eq!(jain_fairness(&[]), 1.0);
        assert_eq!(jain_fairness(&[3.0]), 1.0);
        assert_eq!(jain_fairness(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn collector_discards_warmup() {
        let mut c = Collector::new(2, 10);
        for i in 0..5 {
            c.record(i as f64);
        }
        assert_eq!(c.samples(), &[2.0, 3.0, 4.0]);
    }

    #[test]
    fn cv_zero_mean_guard() {
        assert_eq!(coefficient_of_variation(&[0.0, 0.0, 0.0]), 0.0);
    }
}
