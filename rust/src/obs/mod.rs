//! Observability: span tracing and daemon telemetry.
//!
//! The benchmark's reporting surfaces are *reductions* — windowed series,
//! summary rows, scorecards. This module keeps the un-reduced story: what
//! happened, when, on which lane. It has two halves, both zero-dependency
//! like the rest of the crate:
//!
//! - **Span tracing** ([`trace`] + [`chrome`]): replay engines record
//!   [`trace::VSpan`]s (complete spans and instant markers) into
//!   per-task buffers, the executor seam merges them deterministically
//!   by input index ([`trace::SpanSink`]), and [`chrome`] renders Chrome
//!   trace-event JSON viewable in Perfetto / `chrome://tracing`. Two
//!   clock domains never mix in one file: *virtual-time* traces
//!   (dynsim / cluster replays) derive purely from the deterministic
//!   replay and are byte-identical at any `--jobs`, while *wall-clock*
//!   traces (executor task lanes for `run` / `sweep`) carry host
//!   timings and are quarantined exactly like the JSON `execution`
//!   objects — reported, never gated.
//! - **Telemetry** ([`counters`]): plain counters and bucketed
//!   histograms the serve daemon aggregates over its lifetime (jobs per
//!   state, queue depth, queue-wait / scheduler-idle / worker-idle,
//!   task throughput), snapshotted over the NDJSON `stats` request and
//!   rendered as a table or Prometheus text exposition format for
//!   scraping a warm daemon.
//!
//! See `docs/observability.md` for the span model, the clock-domain
//! quarantine rule, and viewer/scrape walkthroughs.

pub mod chrome;
pub mod counters;
pub mod trace;
