//! Chrome trace-event JSON rendering (Perfetto / `chrome://tracing`).
//!
//! Emits the JSON-object flavour of the trace-event format: a
//! `traceEvents` array of complete (`ph: "X"`), instant (`ph: "i"`) and
//! metadata (`ph: "M"`) events. Two renderers, one per clock domain —
//! never mixed in one file:
//!
//! - [`render_virtual`]: one *process* per replayed timeline, one
//!   *thread* per tenant lane, timestamps on the replay's deterministic
//!   virtual-time axis. Byte-identical at any `--jobs`.
//! - [`render_wall`]: one process for the executor, one thread per
//!   worker, one complete span per executed task on the host clock.
//!   Wall-clock data — quarantined like the JSON `execution` objects,
//!   reported but never gated or byte-compared.
//!
//! Timestamps (`ts` / `dur`) are microseconds; spans carry nanoseconds,
//! so values are formatted as fixed-point `µs.nnn` strings — integer
//! arithmetic only, no float formatting in the deterministic path.

use crate::coordinator::executor::ExecutionStats;
use crate::report::json::{array, quote, Obj};

use super::trace::TaskSpans;

/// Fixed-point microseconds with nanosecond resolution (`1234.567`).
fn us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1_000, ns % 1_000)
}

/// One `ph: "M"` metadata event (`process_name` / `thread_name` / …).
fn meta(name: &str, pid: usize, tid: u64, arg_name: &str) -> String {
    Obj::new()
        .str("ph", "M")
        .str("name", name)
        .field("pid", pid.to_string())
        .field("tid", tid.to_string())
        .field("args", Obj::new().str("name", arg_name).build())
        .build()
}

/// Wrap rendered events in the trace-event JSON object envelope.
fn envelope(events: Vec<String>) -> String {
    format!(
        "{{{}: {}, {}: {}}}\n",
        quote("displayTimeUnit"),
        quote("ms"),
        quote("traceEvents"),
        array(events)
    )
}

/// Render virtual-time replay spans: one Chrome process per task (pid =
/// input index + 1), one thread per tenant lane (tid = tenant id; lane 0
/// carries timeline-level markers). Purely a function of the recorded
/// spans — byte-identical whenever the replay is.
pub fn render_virtual(tasks: &[TaskSpans]) -> String {
    let mut events = Vec::new();
    for t in tasks {
        let pid = t.index + 1;
        events.push(meta(
            "process_name",
            pid,
            0,
            &format!("{}/{} (virtual time)", t.system, t.label),
        ));
        // One thread_name per lane, in ascending tid order.
        let mut lanes: Vec<u64> = t.spans.iter().map(|s| lane(s.tenant)).collect();
        lanes.sort_unstable();
        lanes.dedup();
        for l in lanes {
            let name =
                if l == 0 { "timeline".to_string() } else { format!("tenant {l}") };
            events.push(meta("thread_name", pid, l, &name));
        }
        for s in &t.spans {
            let tid = lane(s.tenant);
            let mut o = Obj::new();
            o = match s.dur_ns {
                Some(dur) => o
                    .str("ph", "X")
                    .str("name", s.name)
                    .str("cat", s.cat)
                    .field("pid", pid.to_string())
                    .field("tid", tid.to_string())
                    .field("ts", us(s.start_ns))
                    .field("dur", us(dur)),
                None => o
                    .str("ph", "i")
                    .str("name", s.name)
                    .str("cat", s.cat)
                    .field("pid", pid.to_string())
                    .field("tid", tid.to_string())
                    .field("ts", us(s.start_ns))
                    .str("s", "t"),
            };
            events.push(o.build());
        }
    }
    envelope(events)
}

fn lane(tenant: Option<crate::simgpu::TenantId>) -> u64 {
    tenant.map(u64::from).unwrap_or(0)
}

/// Render executor wall-clock task lanes: one process (pid 1), one
/// thread per worker, one complete span per executed task. Host-timing
/// data — every `ts`/`dur` differs run to run by construction.
pub fn render_wall(stats: &ExecutionStats) -> String {
    let mut events = Vec::new();
    events.push(meta("process_name", 1, 0, "executor (wall clock)"));
    let mut workers: Vec<usize> = stats.tasks.iter().map(|t| t.worker).collect();
    workers.sort_unstable();
    workers.dedup();
    for w in workers {
        events.push(meta("thread_name", 1, w as u64, &format!("worker {w}")));
    }
    for t in &stats.tasks {
        events.push(
            Obj::new()
                .str("ph", "X")
                .str("name", t.metric_id)
                .str("cat", "task")
                .field("pid", "1".to_string())
                .field("tid", t.worker.to_string())
                .field("ts", us(t.start_ns))
                .field("dur", us(t.wall_ns))
                .field("args", Obj::new().str("system", &t.system).build())
                .build(),
        );
    }
    envelope(events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::TaskTiming;
    use crate::obs::trace::VSpan;
    use crate::serve::jsonl::{self, Value};

    fn sample_tasks() -> Vec<TaskSpans> {
        vec![TaskSpans {
            index: 0,
            system: "hami".to_string(),
            label: "churn".to_string(),
            spans: vec![
                VSpan::instant("lifecycle", "arrive", Some(1), 0),
                VSpan::complete("request", "request", Some(1), 1_500, 2_750_250),
            ],
        }]
    }

    #[test]
    fn virtual_trace_parses_with_the_expected_keys() {
        let text = render_virtual(&sample_tasks());
        let v = jsonl::parse(text.trim_end()).expect("valid JSON");
        assert_eq!(v.get("displayTimeUnit").and_then(Value::as_str), Some("ms"));
        let events = v.get("traceEvents").and_then(Value::as_array).unwrap();
        // process_name + thread_name(tenant 1) + 2 spans.
        assert_eq!(events.len(), 4);
        for e in events {
            for key in ["ph", "name", "pid", "tid"] {
                assert!(e.get(key).is_some(), "event lacks {key}");
            }
        }
        let span = events.last().unwrap();
        assert_eq!(span.get("ph").and_then(Value::as_str), Some("X"));
        // 1_500 ns = 1.5 µs; 2_750_250 − 1_500 ns = 2748.75 µs.
        assert_eq!(span.get("ts").and_then(Value::as_f64), Some(1.5));
        assert_eq!(span.get("dur").and_then(Value::as_f64), Some(2748.75));
        let marker = &events[2];
        assert_eq!(marker.get("ph").and_then(Value::as_str), Some("i"));
        assert_eq!(marker.get("s").and_then(Value::as_str), Some("t"));
    }

    #[test]
    fn virtual_trace_is_a_pure_function_of_the_spans() {
        assert_eq!(render_virtual(&sample_tasks()), render_virtual(&sample_tasks()));
    }

    #[test]
    fn wall_trace_renders_one_lane_per_worker() {
        let stats = ExecutionStats {
            jobs: 2,
            tasks: vec![
                TaskTiming {
                    system: "hami".into(),
                    metric_id: "OH-001",
                    wall_ns: 2_500_000,
                    start_ns: 1_000,
                    worker: 1,
                },
                TaskTiming {
                    system: "fcsp".into(),
                    metric_id: "OH-002",
                    wall_ns: 1_000_000,
                    start_ns: 0,
                    worker: 0,
                },
            ],
            wall_ns: 3_000_000,
        };
        let text = render_wall(&stats);
        let v = jsonl::parse(text.trim_end()).expect("valid JSON");
        let events = v.get("traceEvents").and_then(Value::as_array).unwrap();
        // process_name + 2 thread_names + 2 task spans.
        assert_eq!(events.len(), 5);
        let span = &events[3];
        assert_eq!(span.get("name").and_then(Value::as_str), Some("OH-001"));
        assert_eq!(span.get("tid").and_then(Value::as_u64), Some(1));
        assert_eq!(span.get("dur").and_then(Value::as_f64), Some(2500.0));
        assert_eq!(
            span.get("args").and_then(|a| a.get("system")).and_then(Value::as_str),
            Some("hami")
        );
    }
}
