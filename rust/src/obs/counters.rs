//! Daemon telemetry: counters, bucketed histograms, and their renders.
//!
//! The serve daemon owns one [`Telemetry`] for its whole lifetime (no
//! process-global state — tests run many daemons in one process) and
//! folds every job lifecycle transition into it. A `stats` protocol
//! request snapshots it together with the instantaneous queue picture
//! into a [`StatsSnapshot`], which travels as one NDJSON object and
//! renders client-side as a human table or Prometheus text exposition
//! format (`gvbench jobs --stats` / `--stats-format prometheus`).
//!
//! All values here are **host-side operational telemetry** — wall-clock
//! waits, throughputs, queue depths. Like the JSON `execution` objects,
//! they are reported and scraped, never gated or byte-compared.

use crate::anyhow::{Context, Result};
use crate::report::json::{array, num, Obj};
use crate::serve::jsonl::Value;

/// Bucket upper bounds (ms) for the queue-wait / idle-time histograms.
pub const LATENCY_BOUNDS_MS: &[f64] = &[1.0, 5.0, 25.0, 100.0, 500.0, 2500.0];

/// Bucket upper bounds (tasks/s) for the per-job throughput histogram.
pub const THROUGHPUT_BOUNDS: &[f64] = &[1.0, 10.0, 100.0, 1000.0, 10000.0];

/// A fixed-bound bucketed histogram (cumulative-bucket semantics are
/// applied at render time, Prometheus-style).
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: &'static [f64],
    /// Per-bucket counts; one extra slot for the `+Inf` overflow bucket.
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    pub fn new(bounds: &'static [f64]) -> Histogram {
        Histogram { bounds, counts: vec![0; bounds.len() + 1], sum: 0.0, count: 0 }
    }

    /// Record one observation (NaN observations are dropped).
    pub fn record(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        let slot = self.bounds.iter().position(|b| v <= *b).unwrap_or(self.bounds.len());
        self.counts[slot] += 1;
        self.sum += v;
        self.count += 1;
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            bounds: self.bounds.to_vec(),
            counts: self.counts.clone(),
            sum: self.sum,
            count: self.count,
        }
    }
}

/// A histogram frozen for the wire: per-bucket counts aligned with
/// `bounds` plus one overflow slot.
#[derive(Clone, Debug, PartialEq)]
pub struct HistSnapshot {
    pub bounds: Vec<f64>,
    pub counts: Vec<u64>,
    pub sum: f64,
    pub count: u64,
}

impl HistSnapshot {
    fn empty() -> HistSnapshot {
        HistSnapshot { bounds: Vec::new(), counts: vec![0], sum: 0.0, count: 0 }
    }

    fn to_json(&self) -> String {
        Obj::new()
            .field("bounds", array(self.bounds.iter().map(|b| num(*b)).collect()))
            .field("counts", array(self.counts.iter().map(u64::to_string).collect()))
            .num("sum", self.sum)
            .field("count", self.count.to_string())
            .build()
    }

    fn from_value(v: &Value) -> Result<HistSnapshot> {
        let bounds = v
            .get("bounds")
            .and_then(Value::as_array)
            .context("histogram lacks bounds")?
            .iter()
            .map(|b| b.as_f64().context("non-numeric histogram bound"))
            .collect::<Result<Vec<f64>>>()?;
        let counts = v
            .get("counts")
            .and_then(Value::as_array)
            .context("histogram lacks counts")?
            .iter()
            .map(|c| c.as_u64().context("non-integral histogram count"))
            .collect::<Result<Vec<u64>>>()?;
        Ok(HistSnapshot {
            bounds,
            counts,
            sum: v.get("sum").and_then(Value::as_f64).unwrap_or(0.0),
            count: v.get("count").and_then(Value::as_u64).context("histogram lacks count")?,
        })
    }
}

/// The daemon's lifetime accumulators. Owned by the daemon's shared
/// state, mutated under its lock at each lifecycle transition.
pub struct Telemetry {
    /// Jobs accepted since daemon start (monotonic).
    pub jobs_submitted: u64,
    /// Jobs that reached `finished` (monotonic).
    pub jobs_finished: u64,
    /// Jobs that reached `failed` (monotonic).
    pub jobs_failed: u64,
    /// Executor tasks completed across all jobs (monotonic).
    pub tasks_completed: u64,
    /// Submit→schedule latency per job, ms.
    pub queue_wait_ms: Histogram,
    /// Scheduler idle gap before each job, ms.
    pub scheduler_idle_ms: Histogram,
    /// Worker-side idle capacity per job, ms.
    pub worker_idle_ms: Histogram,
    /// Per-job task throughput, tasks/s of job wall-clock.
    pub job_tasks_per_sec: Histogram,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry {
            jobs_submitted: 0,
            jobs_finished: 0,
            jobs_failed: 0,
            tasks_completed: 0,
            queue_wait_ms: Histogram::new(LATENCY_BOUNDS_MS),
            scheduler_idle_ms: Histogram::new(LATENCY_BOUNDS_MS),
            worker_idle_ms: Histogram::new(LATENCY_BOUNDS_MS),
            job_tasks_per_sec: Histogram::new(THROUGHPUT_BOUNDS),
        }
    }

    /// Fold in one job's schedule-time accounting.
    pub fn record_scheduled(&mut self, queue_wait_ms: f64, scheduler_idle_ms: f64) {
        self.queue_wait_ms.record(queue_wait_ms);
        self.scheduler_idle_ms.record(scheduler_idle_ms);
    }

    /// Fold in one job's terminal accounting.
    pub fn record_done(&mut self, ok: bool, tasks: u64, wall_ms: f64, worker_idle_ms: f64) {
        if ok {
            self.jobs_finished += 1;
        } else {
            self.jobs_failed += 1;
        }
        self.tasks_completed += tasks;
        self.worker_idle_ms.record(worker_idle_ms);
        if wall_ms > 0.0 {
            self.job_tasks_per_sec.record(tasks as f64 / (wall_ms / 1e3));
        }
    }
}

impl Default for Telemetry {
    fn default() -> Telemetry {
        Telemetry::new()
    }
}

/// The `stats` answer: lifetime accumulators plus the instantaneous
/// queue/state picture at snapshot time.
#[derive(Clone, Debug, PartialEq)]
pub struct StatsSnapshot {
    /// Worker threads in the daemon's pool.
    pub workers: u64,
    /// Jobs accepted but not yet claimed by the scheduler.
    pub queue_depth: u64,
    /// Current job counts per state (`queued`/`running`/`finished`/`failed`).
    pub jobs_queued: u64,
    pub jobs_running: u64,
    pub jobs_finished: u64,
    pub jobs_failed: u64,
    /// Jobs accepted since daemon start.
    pub jobs_submitted: u64,
    /// Executor tasks completed across all jobs.
    pub tasks_completed: u64,
    pub queue_wait_ms: HistSnapshot,
    pub scheduler_idle_ms: HistSnapshot,
    pub worker_idle_ms: HistSnapshot,
    pub job_tasks_per_sec: HistSnapshot,
}

impl StatsSnapshot {
    /// Freeze the lifetime accumulators together with the daemon's
    /// instantaneous queue picture.
    pub fn capture(
        t: &Telemetry,
        workers: u64,
        queue_depth: u64,
        jobs_queued: u64,
        jobs_running: u64,
    ) -> StatsSnapshot {
        StatsSnapshot {
            workers,
            queue_depth,
            jobs_queued,
            jobs_running,
            jobs_finished: t.jobs_finished,
            jobs_failed: t.jobs_failed,
            jobs_submitted: t.jobs_submitted,
            tasks_completed: t.tasks_completed,
            queue_wait_ms: t.queue_wait_ms.snapshot(),
            scheduler_idle_ms: t.scheduler_idle_ms.snapshot(),
            worker_idle_ms: t.worker_idle_ms.snapshot(),
            job_tasks_per_sec: t.job_tasks_per_sec.snapshot(),
        }
    }

    /// Encode as the JSON payload of a `stats` response (one line).
    pub fn to_json(&self) -> String {
        Obj::new()
            .field("workers", self.workers.to_string())
            .field("queue_depth", self.queue_depth.to_string())
            .field(
                "jobs",
                Obj::new()
                    .field("queued", self.jobs_queued.to_string())
                    .field("running", self.jobs_running.to_string())
                    .field("finished", self.jobs_finished.to_string())
                    .field("failed", self.jobs_failed.to_string())
                    .build(),
            )
            .field("jobs_submitted", self.jobs_submitted.to_string())
            .field("tasks_completed", self.tasks_completed.to_string())
            .field("queue_wait_ms", self.queue_wait_ms.to_json())
            .field("scheduler_idle_ms", self.scheduler_idle_ms.to_json())
            .field("worker_idle_ms", self.worker_idle_ms.to_json())
            .field("job_tasks_per_sec", self.job_tasks_per_sec.to_json())
            .build()
    }

    /// Decode a parsed `stats` response payload.
    pub fn from_value(v: &Value) -> Result<StatsSnapshot> {
        let u = |key: &str| -> Result<u64> {
            v.get(key).and_then(Value::as_u64).with_context(|| format!("stats lacks {key}"))
        };
        let jobs = v.get("jobs").context("stats lacks jobs")?;
        let state = |key: &str| -> Result<u64> {
            jobs.get(key)
                .and_then(Value::as_u64)
                .with_context(|| format!("stats jobs lacks {key}"))
        };
        let hist = |key: &str| -> Result<HistSnapshot> {
            match v.get(key) {
                Some(h) => HistSnapshot::from_value(h)
                    .with_context(|| format!("bad {key} histogram")),
                None => Ok(HistSnapshot::empty()),
            }
        };
        Ok(StatsSnapshot {
            workers: u("workers")?,
            queue_depth: u("queue_depth")?,
            jobs_queued: state("queued")?,
            jobs_running: state("running")?,
            jobs_finished: state("finished")?,
            jobs_failed: state("failed")?,
            jobs_submitted: u("jobs_submitted")?,
            tasks_completed: u("tasks_completed")?,
            queue_wait_ms: hist("queue_wait_ms")?,
            scheduler_idle_ms: hist("scheduler_idle_ms")?,
            worker_idle_ms: hist("worker_idle_ms")?,
            job_tasks_per_sec: hist("job_tasks_per_sec")?,
        })
    }

    /// Human-readable table (`gvbench jobs --stats`).
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str("counter                value\n");
        out.push_str("---------------------  -----\n");
        let mut row = |name: &str, value: String| {
            out.push_str(&format!("{name:<21}  {value}\n"));
        };
        row("workers", self.workers.to_string());
        row("queue depth", self.queue_depth.to_string());
        row("jobs queued", self.jobs_queued.to_string());
        row("jobs running", self.jobs_running.to_string());
        row("jobs finished", self.jobs_finished.to_string());
        row("jobs failed", self.jobs_failed.to_string());
        row("jobs submitted", self.jobs_submitted.to_string());
        row("tasks completed", self.tasks_completed.to_string());
        let mut hist = |name: &str, h: &HistSnapshot| {
            let mean = if h.count > 0 { h.sum / h.count as f64 } else { 0.0 };
            out.push_str(&format!(
                "{name:<21}  n={} mean={mean:.3}\n",
                h.count
            ));
        };
        hist("queue wait (ms)", &self.queue_wait_ms);
        hist("scheduler idle (ms)", &self.scheduler_idle_ms);
        hist("worker idle (ms)", &self.worker_idle_ms);
        hist("job tasks/sec", &self.job_tasks_per_sec);
        out
    }

    /// Prometheus text exposition format (`--stats-format prometheus`).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut gauge = |name: &str, help: &str, v: u64| {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
        };
        gauge("gvbench_workers", "Worker threads in the daemon pool.", self.workers);
        gauge("gvbench_queue_depth", "Jobs accepted but not yet scheduled.", self.queue_depth);
        out.push_str("# HELP gvbench_jobs Current jobs per lifecycle state.\n");
        out.push_str("# TYPE gvbench_jobs gauge\n");
        for (state, v) in [
            ("queued", self.jobs_queued),
            ("running", self.jobs_running),
            ("finished", self.jobs_finished),
            ("failed", self.jobs_failed),
        ] {
            out.push_str(&format!("gvbench_jobs{{state=\"{state}\"}} {v}\n"));
        }
        let mut counter = |name: &str, help: &str, v: u64| {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"));
        };
        counter(
            "gvbench_jobs_submitted_total",
            "Jobs accepted since daemon start.",
            self.jobs_submitted,
        );
        counter(
            "gvbench_tasks_completed_total",
            "Executor tasks completed across all jobs.",
            self.tasks_completed,
        );
        let mut hist = |name: &str, help: &str, h: &HistSnapshot| {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
            let mut cumulative = 0u64;
            for (i, c) in h.counts.iter().enumerate() {
                cumulative += c;
                let le = match h.bounds.get(i) {
                    Some(b) => prom_bound(*b),
                    None => "+Inf".to_string(),
                };
                out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cumulative}\n"));
            }
            out.push_str(&format!("{name}_sum {}\n", prom_float(h.sum)));
            out.push_str(&format!("{name}_count {}\n", h.count));
        };
        hist(
            "gvbench_queue_wait_ms",
            "Submit-to-schedule latency per job, ms.",
            &self.queue_wait_ms,
        );
        hist(
            "gvbench_scheduler_idle_ms",
            "Scheduler idle gap before each job, ms.",
            &self.scheduler_idle_ms,
        );
        hist(
            "gvbench_worker_idle_ms",
            "Worker-side idle capacity per job, ms.",
            &self.worker_idle_ms,
        );
        hist(
            "gvbench_job_tasks_per_sec",
            "Per-job task throughput, tasks per second.",
            &self.job_tasks_per_sec,
        );
        out
    }
}

/// A bucket bound for a `le` label: integral bounds print without a
/// fraction (`le="25"`), matching common exposition style.
fn prom_bound(b: f64) -> String {
    if b == b.trunc() {
        (b as i64).to_string()
    } else {
        b.to_string()
    }
}

/// A float sample value; exposition format wants a plain decimal.
fn prom_float(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        (v as i64).to_string()
    } else {
        v.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::jsonl;

    fn sample() -> StatsSnapshot {
        let mut t = Telemetry::new();
        t.jobs_submitted = 3;
        t.record_scheduled(0.5, 12.0);
        t.record_scheduled(30.0, 0.25);
        t.record_done(true, 4, 2000.0, 3.0);
        t.record_done(false, 0, 1.0, 0.0);
        StatsSnapshot {
            workers: 2,
            queue_depth: 1,
            jobs_queued: 1,
            jobs_running: 0,
            jobs_finished: 1,
            jobs_failed: 1,
            jobs_submitted: t.jobs_submitted,
            tasks_completed: t.tasks_completed,
            queue_wait_ms: t.queue_wait_ms.snapshot(),
            scheduler_idle_ms: t.scheduler_idle_ms.snapshot(),
            worker_idle_ms: t.worker_idle_ms.snapshot(),
            job_tasks_per_sec: t.job_tasks_per_sec.snapshot(),
        }
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(LATENCY_BOUNDS_MS);
        h.record(0.5); // le=1
        h.record(1.0); // le=1 (inclusive bound)
        h.record(80.0); // le=100
        h.record(1e6); // +Inf overflow
        h.record(f64::NAN); // dropped
        let s = h.snapshot();
        assert_eq!(s.count, 4);
        assert_eq!(s.counts[0], 2);
        assert_eq!(s.counts[3], 1);
        assert_eq!(*s.counts.last().unwrap(), 1);
        assert_eq!(s.sum, 0.5 + 1.0 + 80.0 + 1e6);
    }

    #[test]
    fn snapshot_round_trips_through_jsonl() {
        let snap = sample();
        let wire = snap.to_json();
        let back = StatsSnapshot::from_value(&jsonl::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.workers, 2);
        assert_eq!(back.queue_depth, 1);
        assert_eq!(back.jobs_finished, 1);
        assert_eq!(back.jobs_failed, 1);
        assert_eq!(back.jobs_submitted, 3);
        assert_eq!(back.tasks_completed, 4);
        assert_eq!(back.queue_wait_ms.count, 2);
        assert_eq!(back.queue_wait_ms.counts, snap.queue_wait_ms.counts);
        assert_eq!(back.job_tasks_per_sec.count, 1);
    }

    #[test]
    fn prometheus_exposition_shape() {
        let text = sample().render_prometheus();
        assert!(text.contains("# TYPE gvbench_workers gauge\ngvbench_workers 2\n"));
        assert!(text.contains("gvbench_jobs{state=\"finished\"} 1\n"));
        assert!(text.contains("# TYPE gvbench_jobs_submitted_total counter\n"));
        assert!(text.contains("# TYPE gvbench_queue_wait_ms histogram\n"));
        // Buckets are cumulative and end at +Inf == _count.
        assert!(text.contains("gvbench_queue_wait_ms_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("gvbench_queue_wait_ms_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("gvbench_queue_wait_ms_count 2\n"));
        // Every non-comment line is `name{labels} value` or `name value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "bad sample value in `{line}`");
            assert!(parts.next().is_some(), "no metric name in `{line}`");
        }
    }

    #[test]
    fn table_lists_every_counter() {
        let text = sample().render_table();
        for needle in
            ["workers", "queue depth", "jobs finished", "tasks completed", "queue wait (ms)"]
        {
            assert!(text.contains(needle), "table lacks {needle}:\n{text}");
        }
    }
}
