//! Span records and their deterministic collection across worker threads.
//!
//! A [`VSpan`] is one event on a replay's **virtual-time** axis: a
//! complete span (known start and duration) or an instant marker. The
//! replay engines (`dynsim::engine`, `cluster`) record them as pure
//! observations — recording must never perturb the replay's numbers,
//! which stay byte-identical with tracing on or off.
//!
//! One replay task's spans travel as a [`TaskSpans`] bundle. Worker
//! threads push bundles into a shared [`SpanSink`] in *completion*
//! order; [`SpanSink::drain_sorted`] re-orders them by input index, so
//! the merged trace is a pure function of the task list — bit-identical
//! at any worker count, mirroring the executor's result-slot contract.

use std::sync::Mutex;

use crate::simgpu::TenantId;

/// One virtual-time event: a complete span or an instant marker.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VSpan {
    /// Event category (Chrome `cat`): `request`, `train`, `lifecycle`,
    /// `fault`, `placement`, …
    pub cat: &'static str,
    /// Event name (Chrome `name`): `request`, `prefill`, `fwd`,
    /// `allreduce`, `arrive`, …
    pub name: &'static str,
    /// Tenant lane the event belongs to; `None` renders on the
    /// timeline-level lane 0.
    pub tenant: Option<TenantId>,
    /// Start offset on the virtual-time axis, ns.
    pub start_ns: u64,
    /// Duration, ns; `None` marks an instant event.
    pub dur_ns: Option<u64>,
}

impl VSpan {
    /// A complete span from `start_ns` to `end_ns` (duration saturates
    /// at zero, so a degenerate span never renders end-before-start).
    pub fn complete(
        cat: &'static str,
        name: &'static str,
        tenant: Option<TenantId>,
        start_ns: u64,
        end_ns: u64,
    ) -> VSpan {
        VSpan { cat, name, tenant, start_ns, dur_ns: Some(end_ns.saturating_sub(start_ns)) }
    }

    /// An instant marker at `at_ns`.
    pub fn instant(
        cat: &'static str,
        name: &'static str,
        tenant: Option<TenantId>,
        at_ns: u64,
    ) -> VSpan {
        VSpan { cat, name, tenant, start_ns: at_ns, dur_ns: None }
    }

    /// End offset, ns (the start itself for instant events).
    pub fn end_ns(&self) -> u64 {
        self.start_ns + self.dur_ns.unwrap_or(0)
    }
}

/// The spans of one executor task (one replayed timeline / fleet cell),
/// tagged with its input coordinates for deterministic merging.
#[derive(Clone, Debug)]
pub struct TaskSpans {
    /// Input index in the executor's task list.
    pub index: usize,
    /// System key of the task (`native` / `hami` / …).
    pub system: String,
    /// Timeline label (scenario key or fleet-cell label).
    pub label: String,
    /// Spans in the order the replay recorded them.
    pub spans: Vec<VSpan>,
}

/// Shared collection point for [`TaskSpans`] pushed from worker threads.
///
/// Completion order is nondeterministic; [`SpanSink::drain_sorted`]
/// restores input order, which is all the determinism the trace needs —
/// within one task the replay records spans deterministically.
#[derive(Default)]
pub struct SpanSink {
    tasks: Mutex<Vec<TaskSpans>>,
}

impl SpanSink {
    pub fn new() -> SpanSink {
        SpanSink::default()
    }

    /// Record one task's spans (called from worker threads).
    pub fn push(&self, t: TaskSpans) {
        self.tasks.lock().unwrap().push(t);
    }

    /// Take every recorded bundle, re-ordered by input index.
    pub fn drain_sorted(&self) -> Vec<TaskSpans> {
        let mut tasks = std::mem::take(&mut *self.tasks.lock().unwrap());
        tasks.sort_by_key(|t| t.index);
        tasks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_spans_saturate_and_report_their_end() {
        let s = VSpan::complete("request", "request", Some(1), 100, 350);
        assert_eq!(s.dur_ns, Some(250));
        assert_eq!(s.end_ns(), 350);
        // A clock hiccup must not produce end-before-start.
        let s = VSpan::complete("request", "request", Some(1), 400, 350);
        assert_eq!(s.dur_ns, Some(0));
        let i = VSpan::instant("lifecycle", "arrive", None, 42);
        assert_eq!(i.dur_ns, None);
        assert_eq!(i.end_ns(), 42);
    }

    #[test]
    fn sink_merges_by_input_index_regardless_of_push_order() {
        let sink = SpanSink::new();
        for index in [2usize, 0, 1] {
            sink.push(TaskSpans {
                index,
                system: "hami".to_string(),
                label: format!("sc{index}"),
                spans: vec![VSpan::instant("lifecycle", "arrive", Some(1), index as u64)],
            });
        }
        let tasks = sink.drain_sorted();
        assert_eq!(tasks.iter().map(|t| t.index).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(tasks[1].label, "sc1");
        // Draining empties the sink.
        assert!(sink.drain_sorted().is_empty());
    }
}
