//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from Rust. Python never runs
//! on this path — `make artifacts` lowers the model once at build time
//! (see `python/compile/aot.py`), and this module compiles + executes the
//! HLO through the PJRT CPU client (`xla` crate).
//!
//! Interchange is HLO **text**, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod engine;
pub mod manifest;

pub use engine::Engine;
pub use manifest::{ArtifactSpec, Manifest};

/// Default artifacts directory relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory from the current working directory or
/// the crate root (tests run from the workspace root).
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    let candidates = [
        std::path::PathBuf::from(ARTIFACTS_DIR),
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(ARTIFACTS_DIR),
    ];
    candidates.into_iter().find(|p| p.join("manifest.txt").exists())
}
