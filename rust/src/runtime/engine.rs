//! The PJRT execution engine: compile each artifact once, execute many
//! times from the (Rust-only) request path.

use std::collections::HashMap;
use std::path::Path;

use crate::anyhow::{bail, Context, Result};
use crate::xla;

use super::manifest::{ArtifactSpec, Dtype, Manifest};

/// Compiled artifacts over one PJRT client.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Load every artifact in `dir` (must contain `manifest.txt`).
    pub fn load_dir(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        for a in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                a.file.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", a.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {}", a.name))?;
            executables.insert(a.name.clone(), exe);
        }
        Ok(Engine { client, manifest, executables })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Engine> {
        let dir = super::find_artifacts_dir()
            .context("artifacts/ not found — run `make artifacts` first")?;
        Engine::load_dir(&dir)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn names(&self) -> Vec<&str> {
        self.manifest.artifacts.iter().map(|a| a.name.as_str()).collect()
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Execute artifact `name` on f32 input buffers (one `Vec<f32>` per
    /// input, lengths must match the manifest shapes). Returns the flat
    /// f32 outputs, one `Vec` per output.
    pub fn execute_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.get(name).with_context(|| format!("unknown artifact {name}"))?;
        let exe = self.executables.get(name).context("not compiled")?;
        if inputs.len() != spec.inputs.len() {
            bail!("artifact {name} expects {} inputs, got {}", spec.inputs.len(), inputs.len());
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (buf, tspec)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if tspec.dtype != Dtype::F32 {
                bail!("artifact {name} input {i} is not f32");
            }
            if buf.len() != tspec.element_count() {
                bail!(
                    "artifact {name} input {i}: expected {} elements, got {}",
                    tspec.element_count(),
                    buf.len()
                );
            }
            let lit = xla::Literal::vec1(buf);
            let lit = if tspec.dims.is_empty() {
                lit
            } else {
                lit.reshape(&tspec.dims).context("reshape input literal")?
            };
            literals.push(lit);
        }
        let result = exe.execute::<xla::Literal>(&literals).context("execute")?;
        let mut out_lit = result[0][0].to_literal_sync().context("fetch output")?;
        // aot.py lowers with return_tuple=True: unwrap the tuple.
        let elems = out_lit.decompose_tuple().context("decompose tuple")?;
        let mut outs = Vec::with_capacity(elems.len());
        for e in elems {
            outs.push(e.to_vec::<f32>().context("output to_vec")?);
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests require `make artifacts` to have produced the bundle;
    /// they skip (pass vacuously, with a note) when it is absent so the
    /// pure-Rust test suite works standalone.
    fn engine() -> Option<Engine> {
        match Engine::load_default() {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("skipping PJRT test (artifacts missing): {err:#}");
                None
            }
        }
    }

    #[test]
    fn loads_and_lists_artifacts() {
        let Some(e) = engine() else { return };
        assert!(e.platform().to_lowercase().contains("cpu") || !e.platform().is_empty());
        let names = e.names();
        assert!(names.contains(&"attention_fp32"), "names={names:?}");
        assert!(names.contains(&"decode_step_fp32"), "names={names:?}");
    }

    #[test]
    fn attention_matches_reference_shape() {
        let Some(e) = engine() else { return };
        let spec = e.spec("attention_fp32").unwrap().clone();
        let inputs: Vec<Vec<f32>> = spec
            .inputs
            .iter()
            .map(|t| (0..t.element_count()).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect())
            .collect();
        let outs = e.execute_f32("attention_fp32", &inputs).unwrap();
        assert_eq!(outs.len(), spec.outputs);
        // Output has the Q shape; softmax-weighted mixture stays bounded
        // by the V value range.
        assert_eq!(outs[0].len(), spec.inputs[0].element_count());
        assert!(outs[0].iter().all(|x| x.is_finite()));
        let vmax = 0.7;
        assert!(outs[0].iter().all(|x| x.abs() <= vmax), "attention out of range");
    }

    #[test]
    fn wrong_input_count_rejected() {
        let Some(e) = engine() else { return };
        assert!(e.execute_f32("attention_fp32", &[vec![0.0]]).is_err());
    }
}
