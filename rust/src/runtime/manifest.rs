//! The artifact manifest: a line-based description of each lowered
//! computation written by `python/compile/aot.py` alongside the HLO text.
//!
//! Format (one artifact per line):
//!
//! ```text
//! name=decode_fp32 file=decode_fp32.hlo.txt inputs=f32[8,256];f32[256,256] outputs=1
//! ```

use std::path::{Path, PathBuf};

use crate::anyhow::{bail, Context, Result};

/// Element type of an input (only what the bridge supports).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype in manifest: {other}"),
        }
    }
}

/// Shape+dtype of one input tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub dtype: Dtype,
    pub dims: Vec<i64>,
}

impl TensorSpec {
    /// Parse `f32[8,256]`.
    fn parse(s: &str) -> Result<TensorSpec> {
        let open = s.find('[').context("missing [ in tensor spec")?;
        let dtype = Dtype::parse(&s[..open])?;
        let dims_str = s[open + 1..].trim_end_matches(']');
        let dims = if dims_str.is_empty() {
            Vec::new()
        } else {
            dims_str
                .split(',')
                .map(|d| d.trim().parse::<i64>().context("bad dim"))
                .collect::<Result<Vec<i64>>>()?
        };
        Ok(TensorSpec { dtype, dims })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<i64>().max(1) as usize
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn parse(text: &str, base_dir: &Path) -> Result<Manifest> {
        let mut artifacts = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut name = None;
            let mut file = None;
            let mut inputs = Vec::new();
            let mut outputs = 1usize;
            for tok in line.split_whitespace() {
                let (k, v) =
                    tok.split_once('=').with_context(|| format!("line {}: bad token {tok}", i + 1))?;
                match k {
                    "name" => name = Some(v.to_string()),
                    "file" => file = Some(base_dir.join(v)),
                    "inputs" => {
                        for spec in v.split(';').filter(|s| !s.is_empty()) {
                            inputs.push(TensorSpec::parse(spec)?);
                        }
                    }
                    "outputs" => outputs = v.parse().context("bad outputs count")?,
                    _ => {} // forward-compatible: ignore unknown keys
                }
            }
            artifacts.push(ArtifactSpec {
                name: name.with_context(|| format!("line {}: missing name", i + 1))?,
                file: file.with_context(|| format!("line {}: missing file", i + 1))?,
                inputs,
                outputs,
            });
        }
        Ok(Manifest { artifacts })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text, dir)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_line() {
        let m = Manifest::parse(
            "# comment\nname=decode file=decode.hlo.txt inputs=f32[8,256];f32[256,256] outputs=2\n",
            Path::new("/tmp/a"),
        )
        .unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.name, "decode");
        assert_eq!(a.file, PathBuf::from("/tmp/a/decode.hlo.txt"));
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dims, vec![8, 256]);
        assert_eq!(a.inputs[0].element_count(), 2048);
        assert_eq!(a.outputs, 2);
    }

    #[test]
    fn scalar_shape() {
        let t = TensorSpec::parse("f32[]").unwrap();
        assert!(t.dims.is_empty());
        assert_eq!(t.element_count(), 1);
    }

    #[test]
    fn rejects_bad_dtype() {
        assert!(TensorSpec::parse("f64[2]").is_err());
    }

    #[test]
    fn missing_name_errors() {
        assert!(Manifest::parse("file=x.hlo.txt\n", Path::new(".")).is_err());
    }

    #[test]
    fn lookup() {
        let m = Manifest::parse("name=a file=a.hlo.txt inputs=f32[1]\n", Path::new(".")).unwrap();
        assert!(m.get("a").is_some());
        assert!(m.get("b").is_none());
    }
}
