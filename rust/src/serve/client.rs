//! Client side of the serve protocol: connect to a daemon socket,
//! submit jobs, stream lifecycle events, fetch reports, list jobs,
//! request shutdown. Used by `gvbench submit` / `gvbench jobs` and by
//! the in-process round-trip tests.

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::path::Path;

use crate::anyhow::{Context, Result};
use crate::bail;

use super::jsonl::{self, Value};
use super::proto;

/// One row of the daemon's `jobs` listing.
#[derive(Clone, Debug)]
pub struct JobRow {
    pub job: u64,
    pub command: String,
    pub state: String,
    pub priority: i64,
}

/// Terminal outcome of one job as seen by a client: exactly one of
/// `report` (the job finished; `passed` carries the regress verdict
/// when the job was a gate) or `error` (the job failed) is set.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub job: u64,
    pub report: Option<String>,
    pub passed: Option<bool>,
    pub error: Option<String>,
}

struct Conn {
    reader: BufReader<UnixStream>,
    writer: UnixStream,
}

impl Conn {
    fn open(socket: &Path) -> Result<Conn> {
        let stream = UnixStream::connect(socket)
            .with_context(|| format!("connecting to daemon socket {}", socket.display()))?;
        let reader = BufReader::new(stream.try_clone().context("cloning socket stream")?);
        Ok(Conn { reader, writer: stream })
    }

    fn send(&mut self, line: &str) -> Result<()> {
        writeln!(self.writer, "{line}").context("writing to daemon socket")
    }

    fn read_line(&mut self) -> Result<String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).context("reading from daemon socket")?;
        if n == 0 {
            bail!("daemon closed the connection unexpectedly");
        }
        Ok(line.trim_end_matches('\n').to_string())
    }

    /// Read one response line and fail with the daemon's error message
    /// when `ok` is false.
    fn read_ok(&mut self) -> Result<Value> {
        let line = self.read_line()?;
        let v = jsonl::parse(&line).with_context(|| format!("malformed daemon response `{line}`"))?;
        match v.get("ok").and_then(Value::as_bool) {
            Some(true) => Ok(v),
            Some(false) => {
                let msg = v.get("error").and_then(Value::as_str).unwrap_or("unspecified error");
                bail!("daemon refused the request: {msg}")
            }
            None => bail!("daemon response carries no `ok` field: {line}"),
        }
    }
}

/// Submit a job without waiting for it; returns the job id.
pub fn submit(socket: &Path, argv: &[String], priority: i64) -> Result<u64> {
    let mut conn = Conn::open(socket)?;
    conn.send(&proto::submit_request(argv, priority))?;
    let v = conn.read_ok()?;
    v.get("job").and_then(Value::as_u64).context("submit response carries no job id")
}

/// Watch an already-submitted job to its terminal state. `on_event`
/// receives every raw lifecycle event line, including the terminal one.
pub fn watch(
    socket: &Path,
    job: u64,
    on_event: &mut dyn FnMut(&str),
) -> Result<JobOutcome> {
    let mut conn = Conn::open(socket)?;
    watch_on(&mut conn, job, on_event)
}

/// Submit and stream to completion over a single connection.
pub fn submit_and_wait(
    socket: &Path,
    argv: &[String],
    priority: i64,
    on_event: &mut dyn FnMut(&str),
) -> Result<JobOutcome> {
    let mut conn = Conn::open(socket)?;
    conn.send(&proto::submit_request(argv, priority))?;
    let v = conn.read_ok()?;
    let job = v.get("job").and_then(Value::as_u64).context("submit response carries no job id")?;
    watch_on(&mut conn, job, on_event)
}

fn watch_on(conn: &mut Conn, job: u64, on_event: &mut dyn FnMut(&str)) -> Result<JobOutcome> {
    conn.send(&proto::watch_request(job))?;
    conn.read_ok()?;
    let mut outcome = JobOutcome { job, report: None, passed: None, error: None };
    loop {
        let line = conn.read_line().context("event stream ended before the job finished")?;
        let v = jsonl::parse(&line)
            .with_context(|| format!("malformed lifecycle event `{line}`"))?;
        on_event(&line);
        match v.get("event").and_then(Value::as_str) {
            Some("report") => {
                outcome.report =
                    Some(v.get("report").and_then(Value::as_str).unwrap_or("").to_string());
            }
            Some("finished") => {
                outcome.passed = v.get("passed").and_then(Value::as_bool);
                return Ok(outcome);
            }
            Some("failed") => {
                outcome.error = Some(
                    v.get("error")
                        .and_then(Value::as_str)
                        .unwrap_or("unspecified failure")
                        .to_string(),
                );
                return Ok(outcome);
            }
            _ => {}
        }
    }
}

/// Fetch a job's terminal report, blocking until the job completes.
/// A failed job comes back as `Ok` with `error` set — transport
/// problems are the only `Err` path.
pub fn report(socket: &Path, job: u64) -> Result<JobOutcome> {
    let mut conn = Conn::open(socket)?;
    conn.send(&proto::report_request(job))?;
    let line = conn.read_line()?;
    let v = jsonl::parse(&line).with_context(|| format!("malformed daemon response `{line}`"))?;
    match v.get("ok").and_then(Value::as_bool) {
        Some(true) => Ok(JobOutcome {
            job,
            report: Some(v.get("report").and_then(Value::as_str).unwrap_or("").to_string()),
            passed: v.get("passed").and_then(Value::as_bool),
            error: None,
        }),
        Some(false) => Ok(JobOutcome {
            job,
            report: None,
            passed: None,
            error: Some(
                v.get("error").and_then(Value::as_str).unwrap_or("unspecified error").to_string(),
            ),
        }),
        None => bail!("daemon response carries no `ok` field: {line}"),
    }
}

/// List every job the daemon knows about.
pub fn jobs(socket: &Path) -> Result<Vec<JobRow>> {
    let mut conn = Conn::open(socket)?;
    conn.send(&proto::jobs_request())?;
    let v = conn.read_ok()?;
    let items = v.get("jobs").and_then(Value::as_array).context("jobs response has no list")?;
    let mut rows = Vec::with_capacity(items.len());
    for item in items {
        rows.push(JobRow {
            job: item.get("job").and_then(Value::as_u64).context("job row has no id")?,
            command: item
                .get("command")
                .and_then(Value::as_str)
                .unwrap_or("?")
                .to_string(),
            state: item.get("state").and_then(Value::as_str).unwrap_or("?").to_string(),
            priority: item.get("priority").and_then(Value::as_i64).unwrap_or(0),
        });
    }
    Ok(rows)
}

/// Fetch the daemon's telemetry snapshot (`gvbench jobs --stats`).
pub fn stats(socket: &Path) -> Result<crate::obs::counters::StatsSnapshot> {
    let mut conn = Conn::open(socket)?;
    conn.send(&proto::stats_request())?;
    let v = conn.read_ok()?;
    let payload = v.get("stats").context("stats response has no payload")?;
    crate::obs::counters::StatsSnapshot::from_value(payload)
}

/// Ask the daemon to shut down (it drains already-accepted jobs first).
pub fn shutdown(socket: &Path) -> Result<()> {
    let mut conn = Conn::open(socket)?;
    conn.send(&proto::shutdown_request())?;
    conn.read_ok()?;
    Ok(())
}
