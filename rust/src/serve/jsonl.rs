//! Minimal JSON parser for the serve wire protocol.
//!
//! The crate renders JSON by hand ([`crate::report::json`]) but until the
//! serve layer it never had to *read* any — baselines are CSV. The
//! newline-delimited protocol needs a real parser on both ends: the
//! daemon parses request lines, the client parses responses and
//! lifecycle events. This is a small recursive-descent parser over the
//! full JSON grammar (objects, arrays, strings with escapes incl.
//! `\uXXXX` surrogate pairs, numbers, literals) — no external crates,
//! mirroring the offline-build constraint the rest of the crate lives
//! under. Errors name the byte offset so protocol bugs are debuggable
//! from one log line.

use crate::anyhow::{Context, Result};
use crate::bail;

/// A parsed JSON value. Numbers are kept as `f64` — the protocol's
/// integers (job ids, task indices) are far below 2^53 so the round-trip
/// is exact.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Look up a key in an object; `None` for missing keys and
    /// non-objects alike.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer, `None` when it is not a
    /// number or not integral.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 && *n >= i64::MIN as f64 && *n <= i64::MAX as f64 => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse one complete JSON document; trailing content (other than
/// whitespace) is an error, so a protocol line is exactly one value.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing content at byte {} of JSON document", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            match b {
                b' ' | b'\t' | b'\n' | b'\r' => self.pos += 1,
                _ => break,
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        match self.peek() {
            Some(got) if got == b => {
                self.pos += 1;
                Ok(())
            }
            Some(got) => bail!(
                "expected `{}` at byte {}, found `{}`",
                b as char,
                self.pos,
                got as char
            ),
            None => bail!("expected `{}` at byte {}, found end of input", b as char, self.pos),
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().context("unexpected end of JSON document")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            other => bail!("unexpected byte `{}` at offset {}", other as char, self.pos),
        }
    }

    fn literal(&mut self, word: &str, value: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {} (expected `{word}`)", self.pos)
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii digits");
        let n: f64 = text
            .parse()
            .with_context(|| format!("invalid number `{text}` at byte {start}"))?;
        Ok(Value::Num(n))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().context("unterminated string")? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self.peek().context("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => out.push(self.unicode_escape()?),
                        other => bail!("invalid escape `\\{}` at byte {}", other as char, self.pos),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar (protocol strings carry
                    // arbitrary report text, not just ASCII).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .context("invalid UTF-8 in string")?;
                    let c = rest.chars().next().context("unterminated string")?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let start = self.pos;
        if self.bytes.len() < start + 4 {
            bail!("truncated \\u escape at byte {start}");
        }
        let text = std::str::from_utf8(&self.bytes[start..start + 4])
            .context("invalid \\u escape")?;
        let n = u32::from_str_radix(text, 16)
            .with_context(|| format!("invalid \\u escape `{text}` at byte {start}"))?;
        self.pos += 4;
        Ok(n)
    }

    fn unicode_escape(&mut self) -> Result<char> {
        let hi = self.hex4()?;
        let code = if (0xD800..=0xDBFF).contains(&hi) {
            // Surrogate pair: a second `\uXXXX` must follow.
            if self.peek() != Some(b'\\') || self.bytes.get(self.pos + 1) != Some(&b'u') {
                bail!("unpaired high surrogate at byte {}", self.pos);
            }
            self.pos += 2;
            let lo = self.hex4()?;
            if !(0xDC00..=0xDFFF).contains(&lo) {
                bail!("invalid low surrogate at byte {}", self.pos);
            }
            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
        } else {
            hi
        };
        char::from_u32(code).with_context(|| format!("invalid scalar value U+{code:04X}"))
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(fields));
                }
                _ => bail!("expected `,` or `}}` at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => bail!("expected `,` or `]` at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".to_string()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"op": "submit", "argv": ["run", "--quick"], "priority": -2}"#).unwrap();
        assert_eq!(v.get("op").and_then(Value::as_str), Some("submit"));
        assert_eq!(v.get("priority").and_then(Value::as_i64), Some(-2));
        let argv = v.get("argv").and_then(Value::as_array).unwrap();
        assert_eq!(argv.len(), 2);
        assert_eq!(argv[1].as_str(), Some("--quick"));
    }

    #[test]
    fn string_escapes_round_trip() {
        let rendered = crate::report::json::quote("line1\nline2\t\"quoted\" \\slash");
        let v = parse(&rendered).unwrap();
        assert_eq!(v.as_str(), Some("line1\nline2\t\"quoted\" \\slash"));
        // Surrogate pair: U+1F600.
        assert_eq!(parse(r#""😀""#).unwrap().as_str(), Some("😀"));
        assert_eq!(parse(r#""é""#).unwrap().as_str(), Some("é"));
    }

    #[test]
    fn round_trips_obj_builder_output() {
        // The daemon renders with report::json::Obj; the client must
        // parse exactly that dialect.
        let line = crate::report::json::Obj::new()
            .str("event", "task_completed")
            .field("index", "3".to_string())
            .num("value", 1.25)
            .bool("ok", true)
            .field("none", "null".to_string())
            .build();
        let v = parse(&line).unwrap();
        assert_eq!(v.get("event").and_then(Value::as_str), Some("task_completed"));
        assert_eq!(v.get("index").and_then(Value::as_u64), Some(3));
        assert_eq!(v.get("value").and_then(Value::as_f64), Some(1.25));
        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true));
        assert_eq!(v.get("none"), Some(&Value::Null));
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("{\"a\": }").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} trailing").is_err());
        assert!(parse(r#""\ud83d""#).is_err(), "unpaired surrogate");
    }

    #[test]
    fn whitespace_is_tolerated() {
        let v = parse(" { \"a\" : [ 1 , 2 ] } \n").unwrap();
        assert_eq!(v.get("a").and_then(Value::as_array).map(<[Value]>::len), Some(2));
    }
}
