//! FIFO-with-priorities job queue for the serve daemon.
//!
//! Jobs dequeue by highest priority first; within one priority level
//! strictly in submission order (job ids are monotonically increasing,
//! so FIFO-within-priority is "smallest id among the maximum-priority
//! entries"). The queue holds only `(id, priority)` pairs — job payloads
//! live in the daemon's job table — so push/pop stay trivially cheap
//! under the daemon's state lock.

/// Pending job ids ordered by (priority desc, id asc) on pop.
#[derive(Debug, Default)]
pub struct JobQueue {
    /// Kept in push (= id) order; pop scans for the first entry with the
    /// maximum priority, which is the FIFO head of that priority level.
    entries: Vec<(u64, i64)>,
}

impl JobQueue {
    pub fn new() -> JobQueue {
        JobQueue::default()
    }

    /// Enqueue a job. Ids must be pushed in increasing order (the daemon
    /// allocates them from a counter), which is what makes pop's
    /// first-match scan FIFO within a priority level.
    pub fn push(&mut self, id: u64, priority: i64) {
        self.entries.push((id, priority));
    }

    /// Dequeue the next job: highest priority, then oldest submission.
    pub fn pop(&mut self) -> Option<u64> {
        // In a max_by over (priority, then earlier-index-wins), the
        // earlier entry compares Greater on priority ties, so the first
        // job pushed at the winning priority level is the one removed.
        let best = self
            .entries
            .iter()
            .enumerate()
            .max_by(|(ai, a), (bi, b)| a.1.cmp(&b.1).then(bi.cmp(ai)))
            .map(|(i, _)| i)?;
        Some(self.entries.remove(best).0)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_one_priority_level() {
        let mut q = JobQueue::new();
        q.push(1, 0);
        q.push(2, 0);
        q.push(3, 0);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn higher_priority_jumps_the_queue() {
        let mut q = JobQueue::new();
        q.push(1, 0);
        q.push(2, 5);
        q.push(3, 0);
        q.push(4, 5);
        q.push(5, -3);
        assert_eq!(q.pop(), Some(2), "highest priority first");
        assert_eq!(q.pop(), Some(4), "FIFO among equal priorities");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), Some(5), "negative priority runs last");
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_push_pop_keeps_order() {
        let mut q = JobQueue::new();
        q.push(1, 0);
        q.push(2, 1);
        assert_eq!(q.pop(), Some(2));
        q.push(3, 1);
        q.push(4, 2);
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.len(), 0);
    }
}
