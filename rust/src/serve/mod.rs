//! The serve subsystem: a benchmark daemon with one persistent executor
//! worker pool and a FIFO-with-priorities job queue — the paper's
//! "benchmark service" deployment mode, where a warm daemon amortizes
//! pool spin-up across many submissions and CI gates re-run baselines
//! against it instead of cold one-shot processes.
//!
//! A job is the argv of a one-shot CLI invocation (`run`, `sweep`,
//! `dynamics`, `cluster` or `regress`, minus file-output/pool flags).
//! The daemon parses it with the same [`crate::cli::args::Args::parse`]
//! the binary uses and executes it through the same spec-building
//! helpers and `*_on` executor entry points, so a served report is
//! **bit-identical** to the one-shot CLI's — at any daemon worker
//! count, in any queue order, warm or cold. That is a structural
//! guarantee (per-task seeds are pure functions of task coordinates;
//! see [`crate::coordinator::executor`]) and is pinned by
//! `rust/tests/serve_determinism.rs` and CI's `serve-smoke` job.
//!
//! Per job, the daemon streams newline-delimited JSON lifecycle events
//! (`queued` → `scheduled` → `task_completed` × N → `report` →
//! `finished`, or `failed`) carrying explicit idle-time accounting:
//! `queue_wait_ms` (submission → scheduling), `scheduler_idle_ms` (how
//! long the scheduler sat idle before picking the job up) and
//! `worker_idle_ms` (pool-worker starvation inside the job) — modeled
//! on prover-service job results that report scheduler idle waits as
//! first-class outcomes. See `docs/serve.md` for the operator guide.
//!
//! The same accounting feeds the daemon's lifetime telemetry
//! ([`crate::obs::counters::Telemetry`]): counters and bucketed
//! histograms answered whole by the `stats` op and rendered client-side
//! as a table (`gvbench jobs --stats`) or Prometheus text exposition
//! format (`--stats-format prometheus`).
//!
//! Layout: [`jsonl`] (minimal JSON parser — the crate's first, since
//! every other surface only *renders* JSON), [`proto`] (request/event
//! wire format), [`queue`] (priority-then-FIFO ordering), [`daemon`]
//! (socket + scheduler + pool ownership), [`client`] (the `gvbench
//! submit` / `gvbench jobs` side).

pub mod client;
pub mod daemon;
pub mod jsonl;
pub mod proto;
pub mod queue;

pub use daemon::{Daemon, JobState, ServeConfig};
