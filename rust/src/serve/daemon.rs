//! The serve daemon: one persistent executor worker pool, a
//! FIFO-with-priorities job queue, and an NDJSON lifecycle stream per
//! job over a local Unix socket.
//!
//! Thread structure:
//!
//! - **scheduler** (one thread) — owns the
//!   [`crate::coordinator::executor::WorkerPool`]; pops jobs from the
//!   queue and executes them one at a time on the shared pool via the
//!   same [`Backend`] entry points the one-shot CLI uses, so a served
//!   job's report is bit-identical to its CLI equivalent. Tracks its own
//!   idle time between jobs (`scheduler_idle_ms`).
//! - **acceptor** (one thread) — accepts connections and spawns one
//!   handler thread per connection.
//! - **handlers** — parse request lines and answer; `watch` streams a
//!   job's pre-rendered event lines, blocking on the daemon condvar
//!   until new events (or the terminal state) appear.
//!
//! All shared state lives behind one `Mutex<DaemonState>` + `Condvar`;
//! event lines are rendered *before* insertion so watchers only copy
//! strings out under the lock, never format under it.
//!
//! Shutdown (the `shutdown` op): new submissions are refused, the
//! acceptor is poked awake and exits, the scheduler drains every job
//! already accepted and then joins the pool workers — no orphaned
//! threads, and the socket file is removed.

use std::collections::BTreeMap;
use std::io::Write;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::anyhow::{Context, Error, Result};
use crate::bail;
use crate::cli::args::{Args, Command};
use crate::cli::commands;
use crate::coordinator::executor::{
    resolve_jobs, Backend, ExecutionStats, Observer, TaskDone, WorkerPool,
};
use crate::obs::counters::{StatsSnapshot, Telemetry};
use crate::report::Format;

use super::proto::{self, ExecSummary, Request};
use super::queue::JobQueue;

/// Idle connections are dropped after this long so a client that
/// connects and never speaks (or never disconnects) cannot wedge
/// shutdown. Handlers only read between requests — a long-running
/// `watch` is writing, not reading, and is unaffected.
const IDLE_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Daemon configuration: socket path plus persistent pool size
/// (0 = available parallelism).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub socket: PathBuf,
    pub jobs: usize,
}

/// Lifecycle state of one job, as shown in the `jobs` listing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Finished,
    Failed,
}

impl JobState {
    pub fn key(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Finished => "finished",
            JobState::Failed => "failed",
        }
    }

    fn terminal(self) -> bool {
        matches!(self, JobState::Finished | JobState::Failed)
    }
}

struct JobRecord {
    argv: Vec<String>,
    command: String,
    priority: i64,
    state: JobState,
    /// Pre-rendered NDJSON event lines, in emission order. Watchers
    /// stream slices of this under the state lock.
    events: Vec<String>,
    report: Option<String>,
    passed: Option<bool>,
    error: Option<String>,
    queued_at: Instant,
}

struct DaemonState {
    jobs: BTreeMap<u64, JobRecord>,
    queue: JobQueue,
    next_id: u64,
    stop: bool,
    /// Lifetime counters and histograms, folded in at each lifecycle
    /// transition and answered whole by the `stats` op.
    telemetry: Telemetry,
}

struct Shared {
    state: Mutex<DaemonState>,
    cv: Condvar,
    socket: PathBuf,
    /// Resolved pool size, reported by the `stats` op.
    workers: usize,
}

impl Shared {
    /// Append an event line to a job and wake every waiter.
    fn push_event(&self, job: u64, line: String) {
        let mut st = self.state.lock().unwrap();
        if let Some(j) = st.jobs.get_mut(&job) {
            j.events.push(line);
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Flip the stop flag and poke the acceptor awake with a throwaway
    /// self-connection so it can observe the flag.
    fn request_stop(&self) {
        self.state.lock().unwrap().stop = true;
        self.cv.notify_all();
        let _ = UnixStream::connect(&self.socket);
    }
}

/// A running serve daemon. [`Daemon::wait`] blocks until a client sends
/// the `shutdown` op; dropping an un-waited daemon shuts it down too
/// (the in-process path `rust/tests/serve_determinism.rs` leans on).
pub struct Daemon {
    shared: Arc<Shared>,
    workers: usize,
    acceptor: Option<JoinHandle<()>>,
    scheduler: Option<JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Daemon {
    /// Bind the socket and start the scheduler + acceptor threads. A
    /// stale socket file left by a crashed daemon is removed; a *live*
    /// daemon on the same path is an error.
    pub fn start(cfg: ServeConfig) -> Result<Daemon> {
        if cfg.socket.exists() {
            if UnixStream::connect(&cfg.socket).is_ok() {
                bail!("a daemon is already listening on {}", cfg.socket.display());
            }
            std::fs::remove_file(&cfg.socket)
                .with_context(|| format!("removing stale socket {}", cfg.socket.display()))?;
        }
        if let Some(dir) = cfg.socket.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating socket directory {}", dir.display()))?;
            }
        }
        let listener = UnixListener::bind(&cfg.socket)
            .with_context(|| format!("binding {}", cfg.socket.display()))?;
        let workers = resolve_jobs(cfg.jobs);
        let shared = Arc::new(Shared {
            state: Mutex::new(DaemonState {
                jobs: BTreeMap::new(),
                queue: JobQueue::new(),
                next_id: 1,
                stop: false,
                telemetry: Telemetry::new(),
            }),
            cv: Condvar::new(),
            socket: cfg.socket,
            workers,
        });
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let scheduler = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || scheduler_loop(&shared, workers))
        };
        let acceptor = {
            let shared = Arc::clone(&shared);
            let handlers = Arc::clone(&handlers);
            std::thread::spawn(move || accept_loop(&listener, &shared, &handlers))
        };
        Ok(Daemon {
            shared,
            workers,
            acceptor: Some(acceptor),
            scheduler: Some(scheduler),
            handlers,
        })
    }

    /// Resolved worker count of the persistent pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Block until the daemon shuts down (a client's `shutdown` op),
    /// then join every thread and remove the socket file.
    pub fn wait(mut self) -> Result<()> {
        self.join();
        Ok(())
    }

    fn join(&mut self) {
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        let pending: Vec<JoinHandle<()>> = std::mem::take(&mut *self.handlers.lock().unwrap());
        for h in pending {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.shared.socket);
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        if self.acceptor.is_some() || self.scheduler.is_some() {
            self.shared.request_stop();
            self.join();
        }
    }
}

fn accept_loop(
    listener: &UnixListener,
    shared: &Arc<Shared>,
    handlers: &Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    for stream in listener.incoming() {
        if shared.state.lock().unwrap().stop {
            break;
        }
        let Ok(stream) = stream else { break };
        let shared = Arc::clone(shared);
        let handle = std::thread::spawn(move || {
            let _ = handle_connection(stream, &shared);
        });
        handlers.lock().unwrap().push(handle);
    }
}

fn handle_connection(stream: UnixStream, shared: &Shared) -> std::io::Result<()> {
    let _ = stream.set_read_timeout(Some(IDLE_READ_TIMEOUT));
    let reader = std::io::BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    use std::io::BufRead;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match proto::parse_request(&line) {
            Err(e) => writeln!(writer, "{}", proto::error_response(&format!("{e}")))?,
            Ok(Request::Submit { argv, priority }) => {
                writeln!(writer, "{}", submit_job(shared, argv, priority))?;
            }
            Ok(Request::Jobs) => writeln!(writer, "{}", jobs_listing(shared))?,
            Ok(Request::Stats) => writeln!(writer, "{}", stats_answer(shared))?,
            Ok(Request::Watch { job }) => watch_job(shared, &mut writer, job)?,
            Ok(Request::Report { job }) => writeln!(writer, "{}", report_when_done(shared, job))?,
            Ok(Request::Shutdown) => {
                writeln!(writer, "{}", proto::ok_response())?;
                shared.request_stop();
                break;
            }
        }
    }
    Ok(())
}

/// Accept a job: allowlist the command, refuse file/pool flags, record
/// it, enqueue it. Returns the response line.
fn submit_job(shared: &Shared, argv: Vec<String>, priority: i64) -> String {
    let command = match proto::validate_job_argv(&argv) {
        Ok(c) => c.to_string(),
        Err(e) => return proto::error_response(&format!("{e}")),
    };
    let mut st = shared.state.lock().unwrap();
    if st.stop {
        return proto::error_response("daemon is shutting down; job refused");
    }
    let id = st.next_id;
    st.next_id += 1;
    let queued = proto::event_queued(id, &command, priority);
    st.jobs.insert(
        id,
        JobRecord {
            argv,
            command,
            priority,
            state: JobState::Queued,
            events: vec![queued],
            report: None,
            passed: None,
            error: None,
            queued_at: Instant::now(),
        },
    );
    st.queue.push(id, priority);
    st.telemetry.jobs_submitted += 1;
    drop(st);
    shared.cv.notify_all();
    proto::submit_response(id)
}

/// Answer the `stats` op: freeze the lifetime telemetry together with
/// the instantaneous queue picture under one lock acquisition, so the
/// snapshot is internally consistent.
fn stats_answer(shared: &Shared) -> String {
    let st = shared.state.lock().unwrap();
    let count = |s: JobState| st.jobs.values().filter(|j| j.state == s).count() as u64;
    let snap = StatsSnapshot::capture(
        &st.telemetry,
        shared.workers as u64,
        st.queue.len() as u64,
        count(JobState::Queued),
        count(JobState::Running),
    );
    proto::stats_response(&snap)
}

fn jobs_listing(shared: &Shared) -> String {
    let st = shared.state.lock().unwrap();
    let rows: Vec<(u64, String, &'static str, i64)> = st
        .jobs
        .iter()
        .map(|(id, j)| (*id, j.command.clone(), j.state.key(), j.priority))
        .collect();
    proto::jobs_response(&rows)
}

/// Stream a job's event lines from the beginning; returns after the
/// terminal event has been written.
fn watch_job(shared: &Shared, writer: &mut UnixStream, job: u64) -> std::io::Result<()> {
    {
        let st = shared.state.lock().unwrap();
        if !st.jobs.contains_key(&job) {
            return writeln!(writer, "{}", proto::error_response(&format!("unknown job {job}")));
        }
    }
    writeln!(writer, "{}", proto::ok_response())?;
    let mut sent = 0usize;
    loop {
        let (batch, terminal) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                let j = st.jobs.get(&job).expect("existence checked above");
                let terminal = j.state.terminal();
                if j.events.len() > sent || terminal {
                    break (j.events[sent..].to_vec(), terminal);
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        for line in &batch {
            writeln!(writer, "{line}")?;
        }
        sent += batch.len();
        if terminal {
            return Ok(());
        }
    }
}

/// Block until the job is terminal, then answer with its report (or the
/// failure) in one response line.
fn report_when_done(shared: &Shared, job: u64) -> String {
    let mut st = shared.state.lock().unwrap();
    loop {
        let Some(j) = st.jobs.get(&job) else {
            return proto::error_response(&format!("unknown job {job}"));
        };
        match j.state {
            JobState::Finished => {
                return proto::report_response_ok(
                    job,
                    j.report.as_deref().unwrap_or(""),
                    j.passed,
                );
            }
            JobState::Failed => {
                return proto::error_response(j.error.as_deref().unwrap_or("job failed"));
            }
            JobState::Queued | JobState::Running => {}
        }
        st = shared.cv.wait(st).unwrap();
    }
}

/// The scheduler: pop → mark running (emitting `scheduled` with the
/// queue-wait and scheduler-idle split) → execute on the shared pool →
/// mark terminal. On stop it drains everything already accepted, then
/// joins the pool workers.
fn scheduler_loop(shared: &Arc<Shared>, workers: usize) {
    let mut pool = WorkerPool::new(workers);
    let mut idle_since = Instant::now();
    loop {
        let popped = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(id) = st.queue.pop() {
                    let scheduler_idle_ms = idle_since.elapsed().as_secs_f64() * 1e3;
                    let j = st.jobs.get_mut(&id).expect("queued jobs have records");
                    j.state = JobState::Running;
                    let queue_wait_ms = j.queued_at.elapsed().as_secs_f64() * 1e3;
                    j.events.push(proto::event_scheduled(id, queue_wait_ms, scheduler_idle_ms));
                    let argv = j.argv.clone();
                    st.telemetry.record_scheduled(queue_wait_ms, scheduler_idle_ms);
                    break Some((id, argv, queue_wait_ms, scheduler_idle_ms));
                }
                if st.stop {
                    break None;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        shared.cv.notify_all();
        let Some((id, argv, queue_wait_ms, scheduler_idle_ms)) = popped else {
            pool.shutdown();
            return;
        };
        run_job(shared, &pool, id, &argv, queue_wait_ms, scheduler_idle_ms);
        idle_since = Instant::now();
    }
}

struct JobOutput {
    report: String,
    stats: ExecutionStats,
    /// The gate verdict for regress jobs; `None` for the other schemas.
    passed: Option<bool>,
}

fn run_job(
    shared: &Arc<Shared>,
    pool: &WorkerPool,
    id: u64,
    argv: &[String],
    queue_wait_ms: f64,
    scheduler_idle_ms: f64,
) {
    let observer: Observer = {
        let shared = Arc::clone(shared);
        Arc::new(move |done: TaskDone| {
            shared.push_event(id, proto::event_task_completed(id, &done));
        })
    };
    let result = parse_job_args(argv).and_then(|args| execute_job(&args, pool, observer));
    let mut guard = shared.state.lock().unwrap();
    // Split the guard so the job record and the telemetry accumulators
    // can be updated in one critical section.
    let st = &mut *guard;
    let j = st.jobs.get_mut(&id).expect("running job has a record");
    match result {
        Ok(out) => {
            let summary = ExecSummary {
                tasks: out.stats.tasks.len(),
                workers: out.stats.jobs,
                wall_ms: out.stats.wall_ns as f64 / 1e6,
                busy_ms: out.stats.total_task_ns() as f64 / 1e6,
                queue_wait_ms,
                scheduler_idle_ms,
                worker_idle_ms: out.stats.worker_idle_ns() as f64 / 1e6,
            };
            j.events.push(proto::event_report(id, &out.report));
            j.events.push(proto::event_finished(id, out.passed, &summary));
            j.report = Some(out.report);
            j.passed = out.passed;
            j.state = JobState::Finished;
            st.telemetry.record_done(
                true,
                summary.tasks as u64,
                summary.wall_ms,
                summary.worker_idle_ms,
            );
        }
        Err(e) => {
            let msg = e.to_string();
            j.events.push(proto::event_failed(id, &msg));
            j.error = Some(msg);
            j.state = JobState::Failed;
            st.telemetry.record_done(false, 0, 0.0, 0.0);
        }
    }
    drop(guard);
    shared.cv.notify_all();
}

/// Parse a served argv through the same [`Args::parse`] the binary's
/// `main` uses, so a served job accepts exactly the flags its CLI
/// equivalent does and fails with the same messages.
fn parse_job_args(argv: &[String]) -> Result<Args> {
    Args::parse(argv).map_err(|e| Error::msg(e.0))
}

/// Execute one job on the shared pool via the exact spec-building and
/// `*_on` entry points the one-shot CLI paths use — this is what makes a
/// served report bit-identical to its CLI equivalent.
fn execute_job(args: &Args, pool: &WorkerPool, observer: Observer) -> Result<JobOutput> {
    let exec = Backend::Pool(pool);
    let format = Format::from_key(&args.format)
        .with_context(|| format!("unknown format `{}`", args.format))?;
    match args.command {
        Command::Run => {
            let (report, stats) = commands::run_report_on(args, &exec, Some(observer))?;
            Ok(JobOutput { report, stats, passed: None })
        }
        Command::Sweep => {
            let inputs = commands::sweep_inputs(args)?;
            let surface =
                crate::coordinator::sweep::run_sweep_on(&exec, &inputs.cfg, &inputs.spec, Some(observer));
            let report = crate::report::sweep::render(&surface, format);
            Ok(JobOutput { report, stats: surface.stats, passed: None })
        }
        Command::Dynamics => {
            let inputs = commands::dynamics_inputs(args)?;
            let surface = crate::dynsim::run_dynamics_on(&exec, &inputs.cfg, &inputs.spec, Some(observer));
            let report = crate::report::dynamics::render(&surface, format);
            Ok(JobOutput { report, stats: surface.stats, passed: None })
        }
        Command::Cluster => {
            let inputs = commands::cluster_inputs(args)?;
            let surface = crate::cluster::run_cluster_on(&exec, &inputs.cfg, &inputs.spec, Some(observer));
            let report = crate::report::cluster::render(&surface, format);
            Ok(JobOutput { report, stats: surface.stats, passed: None })
        }
        Command::Regress => {
            let (path, baseline) = commands::load_baseline(args)?;
            let trace = commands::load_trace_spec(args)?;
            let cfg = commands::build_config(args)?;
            let outcome = crate::regress::run_regression_with_trace(
                &exec,
                &cfg,
                &baseline,
                args.threshold,
                Some(observer),
                trace.as_ref(),
            )?;
            let report = crate::regress::render_json(&outcome, &path);
            let passed = outcome.passed();
            Ok(JobOutput { report, stats: outcome.stats, passed: Some(passed) })
        }
        _ => bail!("command is not servable"),
    }
}
