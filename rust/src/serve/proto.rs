//! Wire protocol of the serve daemon: newline-delimited JSON over a
//! local Unix socket.
//!
//! Every request and response is exactly one line. Requests are parsed
//! with [`super::jsonl`]; responses and lifecycle events are rendered
//! with [`crate::report::json::Obj`] so the daemon speaks the same JSON
//! dialect as every other report surface in the crate.
//!
//! Requests (`op` selects the verb):
//!
//! - `{"op": "submit", "argv": [...], "priority": N}` →
//!   `{"ok": true, "job": N}` or `{"ok": false, "error": "..."}`
//! - `{"op": "jobs"}` → `{"ok": true, "jobs": [...]}`
//! - `{"op": "watch", "job": N}` → `{"ok": true}` then the job's event
//!   lines from the beginning, ending with the terminal event
//! - `{"op": "report", "job": N}` → blocks until the job is terminal,
//!   then one `{"ok": true, "job": N, "report": "...", ...}` line
//! - `{"op": "stats"}` → `{"ok": true, "stats": {...}}` with the
//!   daemon's telemetry counters and histograms (see
//!   [`crate::obs::counters::StatsSnapshot`])
//! - `{"op": "shutdown"}` → `{"ok": true}`; the daemon drains its queue
//!   and exits
//!
//! Lifecycle events, in emission order per job: `queued` → `scheduled`
//! → `task_completed` (× tasks) → `report` → `finished`, or `failed`
//! terminally at any point after `queued`. The `scheduled` and
//! `finished` events carry the explicit idle-time accounting
//! (`queue_wait_ms`, `scheduler_idle_ms`, `worker_idle_ms`) described in
//! `docs/serve.md`.

use crate::anyhow::{Context, Result};
use crate::bail;
use crate::coordinator::executor::TaskDone;
use crate::report::json::{array, num, quote, Obj};

use super::jsonl::{self, Value};

/// Commands a served job may run. Everything else — `list`, `compare`,
/// `serve` itself — is rejected at submit time.
pub const JOB_COMMANDS: &[&str] = &["run", "sweep", "dynamics", "cluster", "regress"];

/// Flags that make no sense (or are trapdoors) inside a served job:
/// file outputs are replaced by the report stream, config files would
/// make results depend on daemon-host state the submitter can't see,
/// and the worker count is the daemon's, fixed at `gvbench serve` time.
pub const FORBIDDEN_FLAGS: &[&str] = &[
    "--out",
    "--summary-out",
    "--config",
    "--report-json",
    "--report-md",
    "--jobs",
    "--trace-out",
    "--export-trace",
];

/// One parsed request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Submit { argv: Vec<String>, priority: i64 },
    Jobs,
    Watch { job: u64 },
    Report { job: u64 },
    Stats,
    Shutdown,
}

/// Parse one NDJSON request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = jsonl::parse(line).context("malformed request line")?;
    let op = v
        .get("op")
        .and_then(Value::as_str)
        .context("request is missing the string `op` field")?;
    match op {
        "submit" => {
            let argv_val = v
                .get("argv")
                .and_then(Value::as_array)
                .context("submit request is missing the `argv` array")?;
            let mut argv = Vec::with_capacity(argv_val.len());
            for item in argv_val {
                argv.push(
                    item.as_str()
                        .context("submit `argv` entries must all be strings")?
                        .to_string(),
                );
            }
            let priority = match v.get("priority") {
                None => 0,
                Some(p) => p.as_i64().context("submit `priority` must be an integer")?,
            };
            Ok(Request::Submit { argv, priority })
        }
        "jobs" => Ok(Request::Jobs),
        "watch" => Ok(Request::Watch { job: job_field(&v)? }),
        "report" => Ok(Request::Report { job: job_field(&v)? }),
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        other => {
            bail!("unknown op `{other}` (expected submit, jobs, watch, report, stats or shutdown)")
        }
    }
}

fn job_field(v: &Value) -> Result<u64> {
    v.get("job")
        .and_then(Value::as_u64)
        .context("request is missing the integer `job` field")
}

/// Check a job argv at submit time; returns the (allowlisted) command
/// key. Semantic flag errors are deliberately *not* caught here — they
/// surface at schedule time as a `failed` lifecycle event, proving a bad
/// job cannot poison the worker pool.
pub fn validate_job_argv(argv: &[String]) -> Result<&'static str> {
    let first = argv.first().context("job argv is empty")?;
    let cmd = JOB_COMMANDS
        .iter()
        .copied()
        .find(|c| *c == first.as_str())
        .with_context(|| {
            format!(
                "`{first}` is not a servable command (expected one of: {})",
                JOB_COMMANDS.join(", ")
            )
        })?;
    for flag in FORBIDDEN_FLAGS {
        if argv.iter().any(|a| a == flag) {
            bail!(
                "flag {flag} is not allowed in a served job (outputs stream over the socket; \
                 the worker count is fixed by the daemon's --jobs)"
            );
        }
    }
    Ok(cmd)
}

// ---- client-side request builders -----------------------------------

pub fn submit_request(argv: &[String], priority: i64) -> String {
    let items: Vec<String> = argv.iter().map(|a| quote(a)).collect();
    Obj::new()
        .str("op", "submit")
        .field("argv", array(items))
        .field("priority", priority.to_string())
        .build()
}

pub fn jobs_request() -> String {
    Obj::new().str("op", "jobs").build()
}

pub fn watch_request(job: u64) -> String {
    Obj::new().str("op", "watch").field("job", job.to_string()).build()
}

pub fn report_request(job: u64) -> String {
    Obj::new().str("op", "report").field("job", job.to_string()).build()
}

pub fn stats_request() -> String {
    Obj::new().str("op", "stats").build()
}

pub fn shutdown_request() -> String {
    Obj::new().str("op", "shutdown").build()
}

// ---- daemon-side response / event renderers -------------------------

pub fn ok_response() -> String {
    Obj::new().bool("ok", true).build()
}

pub fn error_response(msg: &str) -> String {
    Obj::new().bool("ok", false).str("error", msg).build()
}

pub fn submit_response(job: u64) -> String {
    Obj::new().bool("ok", true).field("job", job.to_string()).build()
}

/// Terminal-report response: the rendered report plus the gate verdict
/// for regress jobs (`passed` is absent for the other schemas).
pub fn report_response_ok(job: u64, report: &str, passed: Option<bool>) -> String {
    let mut o = Obj::new().bool("ok", true).field("job", job.to_string());
    if let Some(p) = passed {
        o = o.bool("passed", p);
    }
    o.str("report", report).build()
}

/// The daemon's telemetry snapshot, nested under `stats` so the
/// envelope stays uniform with every other `ok` response.
pub fn stats_response(snap: &crate::obs::counters::StatsSnapshot) -> String {
    Obj::new().bool("ok", true).field("stats", snap.to_json()).build()
}

/// One row of the `jobs` listing.
pub fn jobs_response(rows: &[(u64, String, &'static str, i64)]) -> String {
    let items: Vec<String> = rows
        .iter()
        .map(|(id, command, state, priority)| {
            Obj::new()
                .field("job", id.to_string())
                .str("command", command)
                .str("state", state)
                .field("priority", priority.to_string())
                .build()
        })
        .collect();
    Obj::new().bool("ok", true).field("jobs", array(items)).build()
}

/// Host-timing summary attached to a job's `finished` event: the
/// executor's per-job wall/busy split plus the daemon-level idle
/// accounting (time the job waited in queue, time the scheduler sat
/// idle before picking it up, time pool workers starved within it).
#[derive(Clone, Debug)]
pub struct ExecSummary {
    pub tasks: usize,
    pub workers: usize,
    pub wall_ms: f64,
    pub busy_ms: f64,
    pub queue_wait_ms: f64,
    pub scheduler_idle_ms: f64,
    pub worker_idle_ms: f64,
}

pub fn event_queued(job: u64, command: &str, priority: i64) -> String {
    Obj::new()
        .str("event", "queued")
        .field("job", job.to_string())
        .str("command", command)
        .field("priority", priority.to_string())
        .build()
}

pub fn event_scheduled(job: u64, queue_wait_ms: f64, scheduler_idle_ms: f64) -> String {
    Obj::new()
        .str("event", "scheduled")
        .field("job", job.to_string())
        .num("queue_wait_ms", queue_wait_ms)
        .num("scheduler_idle_ms", scheduler_idle_ms)
        .build()
}

pub fn event_task_completed(job: u64, done: &TaskDone) -> String {
    Obj::new()
        .str("event", "task_completed")
        .field("job", job.to_string())
        .field("index", done.index.to_string())
        .field("total", done.total.to_string())
        .str("system", &done.system)
        .str("label", &done.label)
        .field("value", num(done.value))
        .build()
}

pub fn event_report(job: u64, report: &str) -> String {
    Obj::new()
        .str("event", "report")
        .field("job", job.to_string())
        .str("report", report)
        .build()
}

pub fn event_finished(job: u64, passed: Option<bool>, x: &ExecSummary) -> String {
    let execution = Obj::new()
        .field("tasks", x.tasks.to_string())
        .field("workers", x.workers.to_string())
        .num("wall_ms", x.wall_ms)
        .num("busy_ms", x.busy_ms)
        .num("queue_wait_ms", x.queue_wait_ms)
        .num("scheduler_idle_ms", x.scheduler_idle_ms)
        .num("worker_idle_ms", x.worker_idle_ms)
        .build();
    let mut o = Obj::new().str("event", "finished").field("job", job.to_string());
    if let Some(p) = passed {
        o = o.bool("passed", p);
    }
    o.field("execution", execution).build()
}

pub fn event_failed(job: u64, error: &str) -> String {
    Obj::new()
        .str("event", "failed")
        .field("job", job.to_string())
        .str("error", error)
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn requests_round_trip_through_the_builders() {
        let argv = s(&["sweep", "--quick", "--tenants", "1,2"]);
        let line = submit_request(&argv, -3);
        assert_eq!(parse_request(&line).unwrap(), Request::Submit { argv, priority: -3 });
        assert_eq!(parse_request(&jobs_request()).unwrap(), Request::Jobs);
        assert_eq!(parse_request(&watch_request(7)).unwrap(), Request::Watch { job: 7 });
        assert_eq!(parse_request(&report_request(9)).unwrap(), Request::Report { job: 9 });
        assert_eq!(parse_request(&stats_request()).unwrap(), Request::Stats);
        assert_eq!(parse_request(&shutdown_request()).unwrap(), Request::Shutdown);
    }

    #[test]
    fn submit_priority_defaults_to_zero() {
        let req = parse_request(r#"{"op": "submit", "argv": ["run"]}"#).unwrap();
        assert_eq!(req, Request::Submit { argv: s(&["run"]), priority: 0 });
    }

    #[test]
    fn malformed_requests_name_the_problem() {
        let e = parse_request("not json").unwrap_err().to_string();
        assert!(e.contains("malformed request line"), "{e}");
        let e = parse_request(r#"{"nope": 1}"#).unwrap_err().to_string();
        assert!(e.contains("missing the string `op`"), "{e}");
        let e = parse_request(r#"{"op": "teleport"}"#).unwrap_err().to_string();
        assert!(e.contains("unknown op `teleport`"), "{e}");
        assert!(e.contains("stats"), "the op listing names every verb: {e}");
        let e = parse_request(r#"{"op": "watch"}"#).unwrap_err().to_string();
        assert!(e.contains("integer `job`"), "{e}");
        let e = parse_request(r#"{"op": "submit", "argv": [1]}"#).unwrap_err().to_string();
        assert!(e.contains("must all be strings"), "{e}");
    }

    #[test]
    fn job_argv_validation_allowlists_commands_and_blocks_file_flags() {
        assert_eq!(validate_job_argv(&s(&["run", "--quick"])).unwrap(), "run");
        assert_eq!(validate_job_argv(&s(&["regress", "--baseline", "b.csv"])).unwrap(), "regress");
        // Daemon-host file *reads* stay allowed, like --baseline: a trace
        // job replays a file the daemon can see.
        assert_eq!(
            validate_job_argv(&s(&["dynamics", "--trace", "t.txt"])).unwrap(),
            "dynamics"
        );
        assert_eq!(
            validate_job_argv(&s(&["regress", "--baseline", "b.csv", "--trace", "t.txt"]))
                .unwrap(),
            "regress"
        );
        let e = validate_job_argv(&s(&[])).unwrap_err().to_string();
        assert!(e.contains("empty"), "{e}");
        let e = validate_job_argv(&s(&["list"])).unwrap_err().to_string();
        assert!(e.contains("not a servable command"), "{e}");
        for flag in FORBIDDEN_FLAGS {
            let e = validate_job_argv(&s(&["run", flag, "x"])).unwrap_err().to_string();
            assert!(e.contains(flag), "{e}");
        }
        // Semantic errors pass submit-time validation: they are the
        // daemon's schedule-time `failed` path.
        assert!(validate_job_argv(&s(&["run", "--system", "not-a-system"])).is_ok());
    }

    #[test]
    fn stats_response_round_trips_through_the_snapshot_parser() {
        use crate::obs::counters::{StatsSnapshot, Telemetry};
        let mut t = Telemetry::default();
        t.jobs_submitted = 3;
        t.record_scheduled(1.5, 0.25);
        t.record_done(true, 8, 12.0, 2.0);
        t.record_done(false, 0, 0.5, 0.0);
        let snap = StatsSnapshot::capture(&t, 4, 0, 1, 0);
        let line = stats_response(&snap);
        assert!(!line.contains('\n'), "response must be one line: {line}");
        let v = super::super::jsonl::parse(&line).unwrap();
        assert_eq!(v.get("ok"), Some(&super::super::jsonl::Value::Bool(true)));
        let parsed = StatsSnapshot::from_value(v.get("stats").unwrap()).unwrap();
        assert_eq!(parsed, snap);
        assert_eq!(parsed.jobs_finished, 1);
        assert_eq!(parsed.jobs_failed, 1);
        assert_eq!(parsed.queue_wait_ms.count, 1);
    }

    #[test]
    fn events_are_single_parseable_lines() {
        let done = TaskDone {
            index: 2,
            total: 8,
            system: "hami".to_string(),
            label: "PCIE-001".to_string(),
            value: f64::NAN,
        };
        let summary = ExecSummary {
            tasks: 8,
            workers: 4,
            wall_ms: 12.5,
            busy_ms: 40.0,
            queue_wait_ms: 1.25,
            scheduler_idle_ms: 0.5,
            worker_idle_ms: 10.0,
        };
        for line in [
            event_queued(1, "sweep", 2),
            event_scheduled(1, 1.25, 0.5),
            event_task_completed(1, &done),
            event_report(1, "a,b\n1,2\n"),
            event_finished(1, Some(true), &summary),
            event_failed(2, "unknown system `mps2`"),
        ] {
            assert!(!line.contains('\n'), "event must be one line: {line}");
            let v = super::super::jsonl::parse(&line).unwrap();
            assert!(v.get("event").is_some(), "{line}");
            assert!(v.get("job").is_some(), "{line}");
        }
        // NaN task values render as null, not as invalid JSON.
        let v = super::super::jsonl::parse(&event_task_completed(1, &done)).unwrap();
        assert_eq!(v.get("value"), Some(&super::super::jsonl::Value::Null));
        // The finished event carries the full idle-time accounting.
        let v = super::super::jsonl::parse(&event_finished(1, None, &summary)).unwrap();
        let exec = v.get("execution").unwrap();
        for key in ["queue_wait_ms", "scheduler_idle_ms", "worker_idle_ms", "busy_ms"] {
            assert!(exec.get(key).is_some(), "missing {key}");
        }
        assert!(v.get("passed").is_none());
    }
}
