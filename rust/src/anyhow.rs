//! In-tree substitute for the `anyhow` crate (offline build, no registry).
//!
//! Provides the small surface the CLI and PJRT runtime use: a string-backed
//! [`Error`] with context chaining, the [`Result`] alias with a defaulted
//! error type, the [`Context`] extension trait for `Result`/`Option`, and a
//! `bail!` macro. Like the real crate, [`Error`] deliberately does not
//! implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// String-backed error with the context chain pre-rendered into the message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

/// `anyhow`-style result alias with a defaulted error type, so
/// `Result<T>` and `collect::<Result<Vec<_>>>()` both work.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach human-readable context to failures, `anyhow`-style.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow::Error::msg(format!($($arg)*)))
    };
}

// Make `use crate::anyhow::bail;` work: `#[macro_export]` places the macro
// at the crate root; re-export it through this module for the idiomatic
// import path.
pub use crate::bail;

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn context_on_result_prepends() {
        let e = io_fail().context("reading x").unwrap_err();
        assert_eq!(e.to_string(), "reading x: gone");
    }

    #[test]
    fn with_context_lazy() {
        let e: Result<()> = io_fail().with_context(|| format!("step {}", 3));
        assert_eq!(e.unwrap_err().to_string(), "step 3: gone");
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 12);
    }

    #[test]
    fn bail_formats() {
        fn inner(x: u32) -> Result<()> {
            if x > 1 {
                bail!("too big: {x}");
            }
            Ok(())
        }
        assert!(inner(0).is_ok());
        assert_eq!(inner(5).unwrap_err().to_string(), "too big: 5");
    }

    #[test]
    fn display_and_debug_match() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        assert_eq!(format!("{e:?}"), "boom");
        assert_eq!(format!("{e:#}"), "boom"); // alternate flag: same chain
    }
}
