//! The driver API front end: contexts, memory, launches, transfers, events.

use std::collections::HashMap;

use crate::simgpu::error::{GpuError, GpuFault};
use crate::simgpu::kernel::KernelDesc;
use crate::simgpu::memory::{AllocError, DevicePtr};
use crate::simgpu::pcie::Direction;
use crate::simgpu::stream::StreamPriority;
use crate::simgpu::{GpuDevice, StreamId, TenantId};
use crate::virt::{TenantConfig, VirtLayer};

/// CUDA-event handle.
pub type EventId = u32;

struct ContextState {
    /// Bytes allocated through this context (per-pointer, for free()).
    allocations: HashMap<DevicePtr, u64>,
}

/// The assembled API: one simulated device + one virtualization layer.
pub struct Api {
    pub dev: GpuDevice,
    pub virt: Box<dyn VirtLayer>,
    contexts: HashMap<TenantId, ContextState>,
    current_ctx: Option<TenantId>,
    /// Pointer → owning tenant (VA isolation check for IS-005).
    owners: HashMap<DevicePtr, TenantId>,
    events: HashMap<EventId, u64>,
    next_event: EventId,
}

impl Api {
    pub fn new(dev: GpuDevice, virt: Box<dyn VirtLayer>) -> Api {
        Api {
            dev,
            virt,
            contexts: HashMap::new(),
            current_ctx: None,
            owners: HashMap::new(),
            events: HashMap::new(),
            next_event: 1,
        }
    }

    /// Convenience: A100 + backend by name.
    pub fn with_backend(backend: &str, seed: u64) -> Api {
        let dev = GpuDevice::a100(seed);
        let virt = crate::virt::by_name(backend)
            .unwrap_or_else(|| panic!("unknown backend {backend}"));
        Api::new(dev, virt)
    }

    /// Current virtual time, ns (the benchmark stopwatch source).
    pub fn now_ns(&self) -> u64 {
        self.dev.clock.now_ns()
    }

    fn check_errors(&mut self, tenant: TenantId) -> Result<(), GpuError> {
        let now = self.dev.clock.now_ns();
        match self.dev.errors.check(tenant, now) {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    // ---- context management (cuCtxCreate / cuCtxDestroy / switch) ------

    /// `cuCtxCreate` + container registration with the virt layer.
    pub fn ctx_create(&mut self, tenant: TenantId, cfg: TenantConfig) -> Result<(), GpuError> {
        if self.contexts.contains_key(&tenant) {
            return Err(GpuError::InvalidValue);
        }
        self.virt.register_tenant(tenant, cfg, &mut self.dev)?;
        let base = self.dev.spec.ctx_create_ns as f64 * self.dev.jitter();
        let extra = self.virt.context_create_overhead_ns(tenant, &mut self.dev);
        self.dev.clock.advance_f(base + extra);
        self.contexts.insert(tenant, ContextState { allocations: HashMap::new() });
        self.current_ctx = Some(tenant);
        Ok(())
    }

    /// `cuCtxDestroy`: releases allocations, clears tenant poison.
    pub fn ctx_destroy(&mut self, tenant: TenantId) -> Result<(), GpuError> {
        let ctx = self.contexts.remove(&tenant).ok_or(GpuError::InvalidContext)?;
        for (ptr, size) in ctx.allocations {
            self.dev.raw_free(ptr);
            self.owners.remove(&ptr);
            self.virt.post_free(tenant, size, &mut self.dev);
        }
        self.virt.unregister_tenant(tenant, &mut self.dev);
        self.dev.errors.recover_tenant(tenant);
        let j = self.dev.jitter();
        self.dev.clock.advance_f(self.dev.spec.ctx_destroy_ns as f64 * j);
        if self.current_ctx == Some(tenant) {
            self.current_ctx = None;
        }
        Ok(())
    }

    /// Switch the current context (SCHED-001). No-op if already current.
    pub fn ctx_switch(&mut self, tenant: TenantId) -> Result<(), GpuError> {
        if !self.contexts.contains_key(&tenant) {
            return Err(GpuError::InvalidContext);
        }
        if self.current_ctx != Some(tenant) {
            let hook = self.virt.hook_overhead_ns(&mut self.dev);
            let j = self.dev.jitter();
            self.dev.clock.advance_f(self.dev.spec.ctx_switch_ns as f64 * j + hook);
            self.current_ctx = Some(tenant);
        }
        Ok(())
    }

    pub fn has_context(&self, tenant: TenantId) -> bool {
        self.contexts.contains_key(&tenant)
    }

    // ---- memory (cuMemAlloc / cuMemFree / cuMemGetInfo) -----------------

    /// `cuMemAlloc` with quota interposition (OH-002, IS-001/002).
    pub fn mem_alloc(&mut self, tenant: TenantId, size: u64) -> Result<DevicePtr, GpuError> {
        self.check_errors(tenant)?;
        if !self.contexts.contains_key(&tenant) {
            return Err(GpuError::InvalidContext);
        }
        // Virtualization admission (quota) — rejection is cheap and early.
        match self.virt.pre_alloc(tenant, size, &mut self.dev) {
            Ok(cost) => {
                self.dev.clock.advance_f(cost);
            }
            Err(e) => {
                // The enforcement path itself costs a hook + check.
                let hook = self.virt.hook_overhead_ns(&mut self.dev);
                self.dev.clock.advance_f(hook + 150.0);
                return Err(e);
            }
        }
        let (result, cost) = self.dev.raw_alloc(size);
        self.dev.clock.advance_f(cost);
        match result {
            Ok(o) => {
                let post = self.virt.post_alloc(tenant, o.reserved, &mut self.dev);
                self.dev.clock.advance_f(post);
                self.contexts.get_mut(&tenant).unwrap().allocations.insert(o.ptr, o.reserved);
                self.owners.insert(o.ptr, tenant);
                Ok(o.ptr)
            }
            Err(AllocError::ZeroSize) => {
                // Roll back the quota reservation.
                self.virt.post_free(tenant, size, &mut self.dev);
                Err(GpuError::InvalidValue)
            }
            Err(_) => {
                self.virt.post_free(tenant, size, &mut self.dev);
                Err(GpuError::OutOfMemory)
            }
        }
    }

    /// `cuMemFree` (OH-003).
    pub fn mem_free(&mut self, tenant: TenantId, ptr: DevicePtr) -> Result<(), GpuError> {
        self.check_errors(tenant)?;
        let ctx = self.contexts.get_mut(&tenant).ok_or(GpuError::InvalidContext)?;
        let size = ctx.allocations.remove(&ptr).ok_or(GpuError::InvalidValue)?;
        let pre = self.virt.pre_free(tenant, &mut self.dev);
        let (freed, cost) = self.dev.raw_free(ptr);
        debug_assert!(freed.is_some());
        let post = self.virt.post_free(tenant, size, &mut self.dev);
        self.owners.remove(&ptr);
        self.dev.clock.advance_f(pre + cost + post);
        Ok(())
    }

    /// Virtualized `cuMemGetInfo`/`nvmlDeviceGetMemoryInfo`.
    pub fn mem_get_info(&mut self, tenant: TenantId) -> (u64, u64) {
        let hook = self.virt.hook_overhead_ns(&mut self.dev);
        self.dev.clock.advance_f(hook);
        self.virt.mem_info(tenant, &self.dev)
    }

    /// Attempt to read device memory at `ptr` from `tenant`'s context —
    /// the cross-tenant leak probe (IS-005). Reading an address you don't
    /// own faults your own context, like CUDA VA isolation.
    pub fn try_read(&mut self, tenant: TenantId, ptr: DevicePtr) -> Result<(), GpuError> {
        self.check_errors(tenant)?;
        match self.owners.get(&ptr) {
            Some(owner) if *owner == tenant => Ok(()),
            _ => {
                self.dev.inject_fault(tenant, GpuFault::IllegalAddress);
                Err(GpuError::IllegalAddress)
            }
        }
    }

    // ---- kernels (cuLaunchKernel) ---------------------------------------

    /// `cuLaunchKernel`: asynchronous. The clock advances by the CPU-side
    /// launch cost only (what OH-001 measures); the kernel body lands on
    /// the stream timeline. Returns the kernel's `(start, end)` span.
    pub fn launch_kernel(
        &mut self,
        tenant: TenantId,
        stream: StreamId,
        kernel: &KernelDesc,
    ) -> Result<(u64, u64), GpuError> {
        self.check_errors(tenant)?;
        if !self.contexts.contains_key(&tenant) {
            return Err(GpuError::InvalidContext);
        }
        let gate = self.virt.gate_launch(tenant, kernel, &mut self.dev);
        let base = self.dev.spec.launch_ns as f64 * self.dev.jitter();
        self.dev.clock.advance_f(base + gate.overhead_ns + gate.throttle_wait_ns);
        let span = self
            .dev
            .raw_launch(tenant, stream, kernel, gate.granted_sms)
            .ok_or(GpuError::InvalidValue)?;
        let sm_frac = (gate.granted_sms as f64 / self.dev.spec.sm_count as f64)
            * kernel.occupancy.clamp(1.0 / 2048.0, 1.0);
        self.virt
            .on_kernel_complete(tenant, sm_frac.min(1.0), (span.1 - span.0) as f64, span.1 as f64);
        Ok(span)
    }

    /// `cuStreamSynchronize`.
    pub fn sync_stream(&mut self, tenant: TenantId, stream: StreamId) -> Result<(), GpuError> {
        let t = self
            .dev
            .streams
            .sync_time(stream, self.dev.clock.now_ns())
            .ok_or(GpuError::InvalidValue)?;
        self.dev.clock.advance_to(t);
        self.check_errors(tenant)
    }

    /// `cuCtxSynchronize` / `cudaDeviceSynchronize`.
    pub fn sync_device(&mut self, tenant: TenantId) -> Result<(), GpuError> {
        let t = self.dev.streams.device_sync_time(self.dev.clock.now_ns());
        self.dev.clock.advance_to(t);
        self.check_errors(tenant)
    }

    /// Create a stream with priority.
    pub fn stream_create(&mut self, priority: StreamPriority) -> StreamId {
        self.dev.clock.advance(800); // cudaStreamCreate cost
        self.dev.create_stream(priority)
    }

    // ---- transfers (cuMemcpyHtoD / DtoH) --------------------------------

    /// Synchronous memcpy. Returns achieved GB/s (PCIE-001/002/004).
    pub fn memcpy(
        &mut self,
        tenant: TenantId,
        dir: Direction,
        bytes: u64,
        pinned: bool,
    ) -> Result<f64, GpuError> {
        self.check_errors(tenant)?;
        let hook = self.virt.hook_overhead_ns(&mut self.dev);
        let (dur, bw) = self.dev.raw_transfer(tenant, dir, bytes, pinned);
        self.dev.clock.advance_f(hook + dur);
        Ok(bw)
    }

    // ---- events (cuEventRecord / cuEventElapsedTime) ---------------------

    /// Record an event on a stream's current tail.
    pub fn event_record(&mut self, stream: StreamId) -> Result<EventId, GpuError> {
        let t = self
            .dev
            .streams
            .sync_time(stream, self.dev.clock.now_ns())
            .ok_or(GpuError::InvalidValue)?;
        let j = self.dev.jitter();
        self.dev.clock.advance_f(self.dev.spec.event_record_ns as f64 * j);
        let id = self.next_event;
        self.next_event += 1;
        self.events.insert(id, t);
        Ok(id)
    }

    /// Elapsed virtual ms between two events.
    pub fn event_elapsed_ms(&self, start: EventId, end: EventId) -> Result<f64, GpuError> {
        let s = self.events.get(&start).ok_or(GpuError::InvalidValue)?;
        let e = self.events.get(&end).ok_or(GpuError::InvalidValue)?;
        Ok((*e as f64 - *s as f64) / 1e6)
    }

    // ---- faults ----------------------------------------------------------

    /// Inject a fault attributed to `tenant` (the ERR harness).
    pub fn inject_fault(&mut self, tenant: TenantId, fault: GpuFault) {
        self.dev.inject_fault(tenant, fault);
    }

    /// Device reset (ERR-002) — destroys all contexts.
    pub fn device_reset(&mut self) {
        let tenants: Vec<TenantId> = self.contexts.keys().copied().collect();
        for t in tenants {
            let _ = self.ctx_destroy(t);
        }
        self.owners.clear();
        let cost = self.dev.reset();
        self.dev.clock.advance_f(cost);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::VirtualClock;

    fn api(backend: &str) -> Api {
        let mut a = Api::with_backend(backend, 42);
        a.dev.spec.jitter_sigma = 0.0;
        a
    }

    fn stopwatch(a: &Api) -> (VirtualClock, u64) {
        (a.dev.clock.clone(), a.dev.clock.now_ns())
    }

    #[test]
    fn native_launch_latency_matches_table4() {
        let mut a = api("native");
        a.ctx_create(1, TenantConfig::unlimited()).unwrap();
        let t0 = a.now_ns();
        a.launch_kernel(1, 0, &KernelDesc::null()).unwrap();
        let dt = (a.now_ns() - t0) as f64 / 1e3;
        assert!((dt - 4.2).abs() < 0.2, "launch = {dt} µs"); // Table 4: 4.2
    }

    #[test]
    fn hami_launch_latency_elevated() {
        let mut a = api("hami");
        a.ctx_create(1, TenantConfig::unlimited()).unwrap();
        let t0 = a.now_ns();
        a.launch_kernel(1, 0, &KernelDesc::null()).unwrap();
        let dt = (a.now_ns() - t0) as f64 / 1e3;
        assert!(dt > 4.8, "hami launch = {dt} µs");
    }

    #[test]
    fn alloc_free_lifecycle() {
        let mut a = api("native");
        a.ctx_create(1, TenantConfig::unlimited()).unwrap();
        let t0 = a.now_ns();
        let ptr = a.mem_alloc(1, 1 << 20).unwrap();
        let alloc_us = (a.now_ns() - t0) as f64 / 1e3;
        assert!((alloc_us - 12.5).abs() < 0.5, "alloc = {alloc_us} µs"); // Table 4
        a.mem_free(1, ptr).unwrap();
        assert!(a.mem_free(1, ptr).is_err()); // double free
    }

    #[test]
    fn quota_enforced_through_api() {
        let mut a = api("hami");
        a.ctx_create(1, TenantConfig::unlimited().with_mem_limit(1 << 30)).unwrap();
        assert!(a.mem_alloc(1, 1 << 29).is_ok());
        assert_eq!(a.mem_alloc(1, 1 << 29), Err(GpuError::QuotaExceeded));
        // Native never rejects on quota.
        let mut n = api("native");
        n.ctx_create(1, TenantConfig::unlimited().with_mem_limit(1 << 20)).unwrap();
        assert!(n.mem_alloc(1, 1 << 22).is_ok());
    }

    #[test]
    fn cross_tenant_read_faults() {
        let mut a = api("hami");
        a.ctx_create(1, TenantConfig::unlimited()).unwrap();
        a.ctx_create(2, TenantConfig::unlimited()).unwrap();
        let p1 = a.mem_alloc(1, 4096).unwrap();
        assert!(a.try_read(1, p1).is_ok());
        assert_eq!(a.try_read(2, p1), Err(GpuError::IllegalAddress));
        // Tenant 2's context is now poisoned (sticky), tenant 1 fine.
        a.dev.clock.advance(100_000);
        assert!(a.launch_kernel(2, 0, &KernelDesc::null()).is_err());
        assert!(a.launch_kernel(1, 0, &KernelDesc::null()).is_ok());
        // Destroy+recreate recovers tenant 2.
        a.ctx_destroy(2).unwrap();
        a.ctx_create(2, TenantConfig::unlimited()).unwrap();
        assert!(a.launch_kernel(2, 0, &KernelDesc::null()).is_ok());
    }

    #[test]
    fn events_measure_kernel_time() {
        let mut a = api("native");
        a.ctx_create(1, TenantConfig::unlimited()).unwrap();
        let e0 = a.event_record(0).unwrap();
        let k = KernelDesc::gemm(1024, 1024, 1024, false);
        a.launch_kernel(1, 0, &k).unwrap();
        let e1 = a.event_record(0).unwrap();
        let ms = a.event_elapsed_ms(e0, e1).unwrap();
        // 2*1024^3/19.5e12 ≈ 0.11 ms.
        assert!(ms > 0.08 && ms < 0.2, "ms={ms}");
    }

    #[test]
    fn sync_advances_to_stream_completion() {
        let mut a = api("native");
        a.ctx_create(1, TenantConfig::unlimited()).unwrap();
        let (_, _) = stopwatch(&a);
        let span = a.launch_kernel(1, 0, &KernelDesc::gemm(2048, 2048, 2048, false)).unwrap();
        assert!(a.now_ns() < span.1); // async
        a.sync_stream(1, 0).unwrap();
        assert_eq!(a.now_ns(), span.1);
    }

    #[test]
    fn memcpy_bandwidths() {
        let mut a = api("native");
        a.ctx_create(1, TenantConfig::unlimited()).unwrap();
        let bw_pinned = a.memcpy(1, Direction::HostToDevice, 1 << 30, true).unwrap();
        let bw_pageable = a.memcpy(1, Direction::HostToDevice, 1 << 30, false).unwrap();
        assert!(bw_pinned > 20.0);
        assert!((bw_pinned / bw_pageable - 2.4).abs() < 0.1);
    }

    #[test]
    fn device_reset_recovers_from_ecc() {
        let mut a = api("native");
        a.ctx_create(1, TenantConfig::unlimited()).unwrap();
        a.inject_fault(1, GpuFault::EccUncorrectable);
        a.dev.clock.advance(5_000_000);
        assert!(a.launch_kernel(1, 0, &KernelDesc::null()).is_err());
        a.device_reset();
        a.ctx_create(1, TenantConfig::unlimited()).unwrap();
        assert!(a.launch_kernel(1, 0, &KernelDesc::null()).is_ok());
    }

    #[test]
    fn mig_context_cheap_hami_expensive() {
        let mut m = api("mig");
        let t0 = m.now_ns();
        m.ctx_create(1, TenantConfig::unlimited().with_sm_limit(0.5)).unwrap();
        let mig_ctx = m.now_ns() - t0;
        let mut h = api("hami");
        let t0 = h.now_ns();
        h.ctx_create(1, TenantConfig::unlimited()).unwrap();
        let hami_ctx = h.now_ns() - t0;
        assert!(hami_ctx > mig_ctx, "hami={hami_ctx} mig={mig_ctx}");
        // Table 4: hami ctx ≈ 312µs.
        let us = hami_ctx as f64 / 1e3;
        assert!((us - 312.0).abs() < 40.0, "hami ctx = {us} µs");
    }

    #[test]
    fn invalid_context_errors() {
        let mut a = api("native");
        assert_eq!(a.mem_alloc(9, 1024), Err(GpuError::InvalidContext));
        assert_eq!(a.launch_kernel(9, 0, &KernelDesc::null()), Err(GpuError::InvalidContext));
        assert_eq!(a.ctx_switch(9), Err(GpuError::InvalidContext));
    }
}
