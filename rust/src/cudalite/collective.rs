//! NCCL-like collectives over a simulated multi-GPU topology
//! (NCCL-001..004).
//!
//! A [`CollectiveCtx`] binds a communicator (n ranks) to a topology and a
//! virtualization-induced bandwidth share. Software virtualization layers
//! intercept the launch of NCCL's internal kernels, adding per-operation
//! overhead; MIG instances cannot span collectives across slices of one
//! GPU, which the paper sidesteps by benchmarking across physical GPUs —
//! we model the same.

use crate::simgpu::nvlink::Topology;
use crate::simgpu::VirtualClock;

/// A communicator over `topology.device_count` ranks.
pub struct CollectiveCtx {
    pub topology: Topology,
    clock: VirtualClock,
    /// Per-operation CPU-side overhead added by the virt layer (hooking
    /// NCCL's kernel launches), ns.
    pub launch_overhead_ns: f64,
    /// Bandwidth share under multi-tenant contention (1.0 = solo).
    pub bw_share: f64,
    pub ops: u64,
}

impl CollectiveCtx {
    pub fn new(topology: Topology, clock: VirtualClock) -> CollectiveCtx {
        CollectiveCtx { topology, clock, launch_overhead_ns: 0.0, bw_share: 1.0, ops: 0 }
    }

    /// Configure the virtualization overhead per collective operation:
    /// `hook_ns` per intercepted launch, `kernels_per_op` launches per
    /// collective (ring algorithms launch one kernel per rank per phase).
    pub fn with_virt_overhead(mut self, hook_ns: f64, kernels_per_op: u32) -> CollectiveCtx {
        self.launch_overhead_ns = hook_ns * kernels_per_op as f64;
        self
    }

    pub fn with_bw_share(mut self, share: f64) -> CollectiveCtx {
        self.bw_share = share.clamp(1e-3, 1.0);
        self
    }

    /// AllReduce of `bytes`; returns latency in µs (NCCL-001).
    pub fn allreduce(&mut self, bytes: u64) -> f64 {
        let t = self.topology.allreduce_ns(bytes, self.bw_share) + self.launch_overhead_ns;
        self.clock.advance_f(t);
        self.ops += 1;
        t / 1e3
    }

    /// AllGather of `bytes` total; returns achieved GB/s (NCCL-002).
    pub fn allgather(&mut self, bytes: u64) -> f64 {
        let t = self.topology.allgather_ns(bytes, self.bw_share) + self.launch_overhead_ns;
        self.clock.advance_f(t);
        self.ops += 1;
        bytes as f64 / t
    }

    /// P2P copy of `bytes`; returns achieved GB/s (NCCL-003).
    pub fn p2p(&mut self, bytes: u64) -> f64 {
        let (t, bw) = self.topology.p2p_ns(bytes, self.bw_share);
        self.clock.advance_f(t + self.launch_overhead_ns);
        self.ops += 1;
        bw
    }

    /// Broadcast of `bytes`; returns achieved GB/s (NCCL-004).
    pub fn broadcast(&mut self, bytes: u64) -> f64 {
        let t = self.topology.broadcast_ns(bytes, self.bw_share) + self.launch_overhead_ns;
        self.clock.advance_f(t);
        self.ops += 1;
        bytes as f64 / t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> CollectiveCtx {
        CollectiveCtx::new(Topology::nvlink_node(4, 300.0), VirtualClock::new())
    }

    #[test]
    fn allreduce_latency_reasonable() {
        let mut c = ctx();
        // 256 MiB over 4 ranks at 300 GB/s: 2*3/4*256MiB/300GB/s ≈ 1.34 ms.
        let us = c.allreduce(256 << 20);
        assert!(us > 1_200.0 && us < 1_600.0, "us={us}");
    }

    #[test]
    fn virt_overhead_additive() {
        let mut solo = ctx();
        let mut virt = ctx().with_virt_overhead(85.0, 8);
        let small = 1024;
        let a = solo.allreduce(small);
        let b = virt.allreduce(small);
        assert!((b - a - 85.0 * 8.0 / 1e3).abs() < 1e-6, "a={a} b={b}");
    }

    #[test]
    fn contention_degrades_bandwidth() {
        let mut solo = ctx();
        let mut contended = ctx().with_bw_share(0.5);
        let bw_solo = solo.allgather(1 << 28);
        let bw_half = contended.allgather(1 << 28);
        assert!(bw_half < bw_solo * 0.6, "solo={bw_solo} half={bw_half}");
    }

    #[test]
    fn clock_advances() {
        let clk = VirtualClock::new();
        let mut c = CollectiveCtx::new(Topology::nvlink_node(2, 300.0), clk.clone());
        c.p2p(1 << 20);
        assert!(clk.now_ns() > 0);
        assert_eq!(c.ops, 1);
    }
}
