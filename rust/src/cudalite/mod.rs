//! `cudalite` — a CUDA-driver-shaped API over the simulated GPU.
//!
//! This is the surface the virtualization layers interpose on, mirroring
//! where HAMi-core's `dlsym` hooks wrap the real `libcuda`. Every call:
//!
//! 1. checks the device error state (sticky errors propagate like CUDA),
//! 2. invokes the virt layer's pre-hooks (interception cost, quota,
//!    throttling),
//! 3. performs the hardware operation on [`crate::simgpu::GpuDevice`],
//! 4. invokes post-hooks (accounting) and advances the virtual clock by
//!    the total CPU-side cost.
//!
//! Benchmarks measure latency by reading the virtual clock around calls —
//! exactly the `clock_gettime` pattern in the paper's Listings 3–4.

pub mod api;
pub mod collective;

pub use api::{Api, EventId};
pub use collective::CollectiveCtx;
