//! Thread-backed tenant harness for the *real-compute* examples: each
//! tenant thread drives the PJRT runtime (or any closure) and reports
//! latency samples back over a channel. The simulated metrics never need
//! threads (virtual time is single-threaded and deterministic); this
//! harness exists for the end-to-end serving example where wall-clock
//! concurrency is the point.

use std::sync::mpsc;
use std::thread;
use std::time::Instant;

/// One latency sample from a tenant.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub tenant: u32,
    pub seq: u64,
    pub latency_ns: u64,
}

/// Spawn `n_tenants` threads, each invoking `work(tenant, seq)` `reps`
/// times, and collect all samples. `work` must be `Send + Clone`.
pub fn run_tenants<F>(n_tenants: u32, reps: u64, work: F) -> Vec<Sample>
where
    F: Fn(u32, u64) + Send + Clone + 'static,
{
    let (tx, rx) = mpsc::channel::<Sample>();
    let mut handles = Vec::new();
    for t in 0..n_tenants {
        let tx = tx.clone();
        let work = work.clone();
        handles.push(thread::spawn(move || {
            for seq in 0..reps {
                let t0 = Instant::now();
                work(t, seq);
                let dt = t0.elapsed().as_nanos() as u64;
                // Receiver may be gone if the caller aborted; ignore.
                let _ = tx.send(Sample { tenant: t, seq, latency_ns: dt });
            }
        }));
    }
    drop(tx);
    let mut samples: Vec<Sample> = rx.into_iter().collect();
    for h in handles {
        h.join().expect("tenant thread panicked");
    }
    samples.sort_by_key(|s| (s.tenant, s.seq));
    samples
}

/// Per-tenant throughput (ops/s) from a sample set and a wall duration.
pub fn throughput_per_tenant(samples: &[Sample], wall_ns: u64, n_tenants: u32) -> Vec<f64> {
    let mut counts = vec![0u64; n_tenants as usize];
    for s in samples {
        counts[s.tenant as usize] += 1;
    }
    counts.iter().map(|c| *c as f64 / (wall_ns as f64 / 1e9)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn all_samples_collected() {
        let counter = Arc::new(AtomicU64::new(0));
        let c = counter.clone();
        let samples = run_tenants(4, 25, move |_, _| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(samples.len(), 100);
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn samples_ordered_per_tenant() {
        let samples = run_tenants(2, 10, |_, _| {});
        for w in samples.windows(2) {
            if w[0].tenant == w[1].tenant {
                assert!(w[0].seq < w[1].seq);
            }
        }
    }

    #[test]
    fn throughput_counts() {
        let samples = run_tenants(2, 50, |_, _| {});
        let thr = throughput_per_tenant(&samples, 1_000_000_000, 2);
        assert_eq!(thr.len(), 2);
        assert!((thr[0] - 50.0).abs() < 1e-9);
    }
}
