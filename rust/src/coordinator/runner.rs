//! The suite runner: executes the 56-metric suite for a set of systems,
//! always including the MIG-Ideal baseline run it scores against
//! (paper §4.5: every metric is compared to the simulated MIG baseline).

use std::collections::HashMap;

use crate::metrics::{registry, Category, MetricResult, RunConfig};
use crate::scoring::ScoreCard;

/// Results for one system plus its scorecard.
pub struct SuiteResult {
    pub system: String,
    pub results: Vec<MetricResult>,
    pub card: ScoreCard,
}

/// Runs suites and keeps the shared MIG baseline.
pub struct SuiteRunner {
    base_cfg: RunConfig,
    /// Restrict to these categories (None = all 56 metrics).
    categories: Option<Vec<Category>>,
    /// Restrict to these metric ids (takes precedence over categories).
    metric_ids: Option<Vec<String>>,
    baseline: Option<Vec<MetricResult>>,
}

impl SuiteRunner {
    pub fn new(base_cfg: RunConfig) -> SuiteRunner {
        SuiteRunner { base_cfg, categories: None, metric_ids: None, baseline: None }
    }

    pub fn with_categories(mut self, cats: Vec<Category>) -> SuiteRunner {
        self.categories = Some(cats);
        self
    }

    pub fn with_metrics(mut self, ids: Vec<String>) -> SuiteRunner {
        self.metric_ids = Some(ids);
        self
    }

    fn run_suite(&self, system: &str) -> Vec<MetricResult> {
        let mut cfg = self.base_cfg.clone();
        cfg.system = system.to_string();
        if let Some(ids) = &self.metric_ids {
            return ids.iter().filter_map(|id| registry::run_metric(id, &cfg)).collect();
        }
        match &self.categories {
            Some(cats) => {
                cats.iter().flat_map(|c| registry::run_category(*c, &cfg)).collect()
            }
            None => registry::run_all(&cfg),
        }
    }

    /// The MIG-Ideal baseline: spec-derived expected values (paper §4.5),
    /// one per metric the runner is configured to execute.
    pub fn baseline(&mut self) -> &[MetricResult] {
        if self.baseline.is_none() {
            let ids: Vec<&'static str> = if let Some(ids) = &self.metric_ids {
                ids.iter()
                    .filter_map(|id| crate::metrics::taxonomy::by_id(id).map(|d| d.id))
                    .collect()
            } else if let Some(cats) = &self.categories {
                cats.iter()
                    .flat_map(|c| crate::metrics::taxonomy::by_category(*c))
                    .map(|d| d.id)
                    .collect()
            } else {
                crate::metrics::taxonomy::ALL.iter().map(|d| d.id).collect()
            };
            self.baseline = Some(
                ids.into_iter()
                    .map(|id| {
                        MetricResult::from_value(
                            id,
                            "mig-ideal-spec",
                            crate::metrics::taxonomy::mig_baseline(id),
                        )
                    })
                    .collect(),
            );
        }
        self.baseline.as_ref().unwrap()
    }

    /// The *measured* MIG suite (for Δ-vs-measured ablations).
    pub fn measured_mig(&self) -> Vec<MetricResult> {
        self.run_suite("mig")
    }

    /// Run one system and score it against the MIG baseline.
    pub fn run(&mut self, system: &str) -> SuiteResult {
        self.baseline();
        let results = self.run_suite(system);
        let card = ScoreCard::build(system, &results, self.baseline.as_ref().unwrap());
        SuiteResult { system: system.to_string(), results, card }
    }

    /// Run several systems; returns results keyed by system name.
    pub fn run_many(&mut self, systems: &[&str]) -> HashMap<String, SuiteResult> {
        systems.iter().map(|s| (s.to_string(), self.run(s))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mig_scores_near_perfect_against_spec_baseline() {
        let mut runner = SuiteRunner::new(RunConfig::quick("mig"))
            .with_metrics(vec!["OH-001".into(), "IS-005".into(), "PCIE-004".into()]);
        let mig = runner.run("mig");
        assert!(mig.card.overall > 0.95, "mig={}", mig.card.overall);
    }

    #[test]
    fn category_restriction() {
        let mut runner = SuiteRunner::new(RunConfig::quick("native"))
            .with_categories(vec![Category::Pcie]);
        let r = runner.run("native");
        assert_eq!(r.results.len(), 4);
        assert!(r.results.iter().all(|m| m.id.starts_with("PCIE")));
    }

    #[test]
    fn metric_restriction_takes_precedence() {
        let mut runner = SuiteRunner::new(RunConfig::quick("native"))
            .with_categories(vec![Category::Pcie])
            .with_metrics(vec!["OH-009".into()]);
        let r = runner.run("native");
        assert_eq!(r.results.len(), 1);
        assert_eq!(r.results[0].id, "OH-009");
    }
}
