//! The suite runner: executes the 56-metric suite for a set of systems,
//! always including the MIG-Ideal baseline run it scores against
//! (paper §4.5: every metric is compared to the simulated MIG baseline).
//!
//! Execution goes through the parallel sharded executor
//! ([`super::executor`]): the metric list shards across `jobs` workers
//! (0 = available parallelism) with per-task derived seeds, so a suite's
//! numbers are bit-identical at any job count; results return in Table-8
//! order and the run's [`ExecutionStats`] ride along on [`SuiteResult`].

use std::collections::HashMap;
use std::sync::Arc;

use crate::metrics::{registry, taxonomy, Category, MetricResult, RunConfig};
use crate::scoring::ScoreCard;

use super::executor::{self, Backend, ExecutionStats, Observer, Task, TaskDone};

/// Results for one system plus its scorecard and execution timings.
pub struct SuiteResult {
    pub system: String,
    pub results: Vec<MetricResult>,
    pub card: ScoreCard,
    /// Wall-clock + per-task timing of the run (host time, not virtual).
    pub stats: ExecutionStats,
}

/// Runs suites and keeps the shared MIG baseline.
pub struct SuiteRunner {
    base_cfg: RunConfig,
    /// Restrict to these categories (None = all 56 metrics).
    categories: Option<Vec<Category>>,
    /// Restrict to these metric ids (takes precedence over categories).
    metric_ids: Option<Vec<String>>,
    baseline: Option<Vec<MetricResult>>,
}

impl SuiteRunner {
    pub fn new(base_cfg: RunConfig) -> SuiteRunner {
        SuiteRunner { base_cfg, categories: None, metric_ids: None, baseline: None }
    }

    pub fn with_categories(mut self, cats: Vec<Category>) -> SuiteRunner {
        self.categories = Some(cats);
        self
    }

    pub fn with_metrics(mut self, ids: Vec<String>) -> SuiteRunner {
        self.metric_ids = Some(ids);
        self
    }

    /// Set the worker count for suite execution (0 = available
    /// parallelism). Results are bit-identical at any value.
    pub fn with_jobs(mut self, jobs: usize) -> SuiteRunner {
        self.base_cfg.jobs = jobs;
        self
    }

    /// The metric ids this runner is configured to execute: explicit ids
    /// (caller order) take precedence over categories (Table-8 order);
    /// default is the full taxonomy.
    fn metric_id_list(&self) -> Vec<&'static str> {
        if let Some(ids) = &self.metric_ids {
            ids.iter().filter_map(|id| taxonomy::by_id(id).map(|d| d.id)).collect()
        } else if let Some(cats) = &self.categories {
            cats.iter().flat_map(|c| taxonomy::by_category(*c)).map(|d| d.id).collect()
        } else {
            taxonomy::ALL.iter().map(|d| d.id).collect()
        }
    }

    fn run_suite(&self, system: &str) -> (Vec<MetricResult>, ExecutionStats) {
        self.run_suite_on(system, &Backend::Scoped(self.base_cfg.jobs), None)
    }

    /// [`Self::run_suite`] generalized over the pool shape: same task
    /// list, same [`executor::derive_cfg`] seed derivation, executed on
    /// `exec` (scoped threads or a persistent serve-daemon pool), with an
    /// optional per-task completion observer. Bit-identical to the scoped
    /// path at any worker count.
    fn run_suite_on(
        &self,
        system: &str,
        exec: &Backend<'_>,
        observer: Option<Observer>,
    ) -> (Vec<MetricResult>, ExecutionStats) {
        let ids = self.metric_id_list();
        let pairs: Vec<(Task, RunConfig)> = ids
            .iter()
            .map(|id| {
                (
                    Task { system: system.to_string(), metric_id: *id },
                    executor::derive_cfg(&self.base_cfg, system, id),
                )
            })
            .collect();
        let tasks: Arc<Vec<Task>> = Arc::new(pairs.iter().map(|(t, _)| t.clone()).collect());
        let total = tasks.len();
        let pairs = Arc::new(pairs);
        let run = {
            let pairs = Arc::clone(&pairs);
            move |i: usize, task: &Task| {
                let result = registry::run_metric(task.metric_id, &pairs[i].1);
                if let (Some(obs), Some(r)) = (observer.as_ref(), result.as_ref()) {
                    obs(TaskDone {
                        index: i,
                        total,
                        system: task.system.clone(),
                        label: task.metric_id.to_string(),
                        value: r.value,
                    });
                }
                result
            }
        };
        let (slots, stats) = executor::execute_indexed_on(exec, tasks, run);
        (slots.into_iter().flatten().collect(), stats)
    }

    /// The MIG-Ideal baseline: spec-derived expected values (paper §4.5),
    /// one per metric the runner is configured to execute.
    pub fn baseline(&mut self) -> &[MetricResult] {
        if self.baseline.is_none() {
            self.baseline = Some(
                self.metric_id_list()
                    .into_iter()
                    .map(|id| {
                        MetricResult::from_value(
                            id,
                            "mig-ideal-spec",
                            taxonomy::mig_baseline(id),
                        )
                    })
                    .collect(),
            );
        }
        self.baseline.as_ref().unwrap()
    }

    /// The *measured* MIG suite (for Δ-vs-measured ablations).
    pub fn measured_mig(&self) -> Vec<MetricResult> {
        self.run_suite("mig").0
    }

    /// Run one system and score it against the MIG baseline.
    pub fn run(&mut self, system: &str) -> SuiteResult {
        self.run_on(system, &Backend::Scoped(self.base_cfg.jobs), None)
    }

    /// [`Self::run`] on an explicit pool shape with an optional per-task
    /// observer — the serve daemon runs suites on its persistent pool
    /// through this; results are bit-identical to [`Self::run`].
    pub fn run_on(
        &mut self,
        system: &str,
        exec: &Backend<'_>,
        observer: Option<Observer>,
    ) -> SuiteResult {
        self.baseline();
        let (results, stats) = self.run_suite_on(system, exec, observer);
        let card = ScoreCard::build(system, &results, self.baseline.as_ref().unwrap());
        SuiteResult { system: system.to_string(), results, card, stats }
    }

    /// Run several systems; returns results keyed by system name.
    pub fn run_many(&mut self, systems: &[&str]) -> HashMap<String, SuiteResult> {
        systems.iter().map(|s| (s.to_string(), self.run(s))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mig_scores_near_perfect_against_spec_baseline() {
        let mut runner = SuiteRunner::new(RunConfig::quick("mig"))
            .with_metrics(vec!["OH-001".into(), "IS-005".into(), "PCIE-004".into()]);
        let mig = runner.run("mig");
        assert!(mig.card.overall > 0.95, "mig={}", mig.card.overall);
    }

    #[test]
    fn category_restriction() {
        let mut runner = SuiteRunner::new(RunConfig::quick("native"))
            .with_categories(vec![Category::Pcie]);
        let r = runner.run("native");
        assert_eq!(r.results.len(), 4);
        assert!(r.results.iter().all(|m| m.id.starts_with("PCIE")));
    }

    #[test]
    fn metric_restriction_takes_precedence() {
        let mut runner = SuiteRunner::new(RunConfig::quick("native"))
            .with_categories(vec![Category::Pcie])
            .with_metrics(vec!["OH-009".into()]);
        let r = runner.run("native");
        assert_eq!(r.results.len(), 1);
        assert_eq!(r.results[0].id, "OH-009");
    }

    #[test]
    fn stats_cover_every_task() {
        let mut runner = SuiteRunner::new(RunConfig::quick("native"))
            .with_categories(vec![Category::Pcie])
            .with_jobs(2);
        let r = runner.run("native");
        assert_eq!(r.stats.tasks.len(), 4);
        assert_eq!(r.stats.jobs, 2);
        assert!(r.stats.wall_ns > 0);
    }

    #[test]
    fn jobs_do_not_change_numbers() {
        let cfg = RunConfig::quick("fcsp");
        let ids = vec!["OH-009".to_string(), "PCIE-004".to_string(), "BW-003".to_string()];
        let mut one =
            SuiteRunner::new(cfg.clone()).with_metrics(ids.clone()).with_jobs(1);
        let mut many = SuiteRunner::new(cfg).with_metrics(ids).with_jobs(4);
        let a = one.run("fcsp");
        let b = many.run("fcsp");
        assert_eq!(a.card.overall.to_bits(), b.card.overall.to_bits());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.value.to_bits(), y.value.to_bits(), "{}", x.id);
        }
    }
}
