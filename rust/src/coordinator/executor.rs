//! Parallel, sharded execution of the (system × metric) task matrix.
//!
//! The full Table-8 evaluation (4 systems × 56 metrics = 224 tasks) used to
//! run strictly sequentially. Every metric is an independent pure function
//! of its [`RunConfig`] — each builds its own simulated device — so the
//! matrix shards perfectly across a worker pool:
//!
//! 1. The caller describes the matrix as a flat `Vec<Task>` in the desired
//!    output (Table-8) order.
//! 2. `--jobs N` scoped threads (default: available parallelism) pull task
//!    indices from a shared atomic cursor — classic work stealing by
//!    sharded index, no channels, no unsafe.
//! 3. Each task derives its own seed with [`task_seed`]`(cfg.seed, system,
//!    metric_id)` — a pure function of the run seed and the task
//!    coordinates — so the numbers are **bit-identical regardless of worker
//!    count or completion order** (see `rust/tests/determinism.rs`).
//! 4. Results land in per-index slots and are re-assembled in input order;
//!    wall-clock and per-task timings are recorded in [`ExecutionStats`]
//!    and surfaced by the JSON/CSV reporters.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::metrics::{registry, MetricResult, RunConfig};
use crate::util::rng::task_seed;

/// One (system, metric) cell of the evaluation matrix.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Task {
    /// Backend key (`native` / `hami` / `fcsp` / `mig` / `timeslice`).
    pub system: String,
    /// Metric id from the Table-8 taxonomy (e.g. `OH-001`).
    pub metric_id: &'static str,
}

/// Wall-clock timing of one executed task.
#[derive(Clone, Debug)]
pub struct TaskTiming {
    pub system: String,
    pub metric_id: &'static str,
    /// Host wall-clock spent executing the task, ns.
    pub wall_ns: u64,
    /// Worker index (0-based) that ran the task.
    pub worker: usize,
}

/// Aggregate statistics for one executor invocation.
#[derive(Clone, Debug, Default)]
pub struct ExecutionStats {
    /// Worker count actually used.
    pub jobs: usize,
    /// Per-task timings, in output (Table-8) order.
    pub tasks: Vec<TaskTiming>,
    /// End-to-end wall-clock of the whole matrix, ns.
    pub wall_ns: u64,
}

impl ExecutionStats {
    /// Sum of per-task wall-clock (the serial-equivalent cost), ns.
    pub fn total_task_ns(&self) -> u64 {
        self.tasks.iter().map(|t| t.wall_ns).sum()
    }

    /// Longest single task, ns (the parallel-speedup floor).
    pub fn max_task_ns(&self) -> u64 {
        self.tasks.iter().map(|t| t.wall_ns).max().unwrap_or(0)
    }

    /// Achieved busy/wall ratio — ≈ the effective parallel speedup over a
    /// serial run of the same tasks.
    pub fn speedup_estimate(&self) -> f64 {
        if self.wall_ns == 0 {
            return 1.0;
        }
        self.total_task_ns() as f64 / self.wall_ns as f64
    }
}

/// Resolve a requested job count: 0 means "available parallelism".
pub fn resolve_jobs(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Build the full matrix for `systems` × `metric_ids`, system-major (all of
/// system 0's metrics in Table-8 order, then system 1, …).
pub fn task_matrix(systems: &[&str], metric_ids: &[&'static str]) -> Vec<Task> {
    systems
        .iter()
        .flat_map(|s| {
            metric_ids.iter().map(move |id| Task { system: s.to_string(), metric_id: *id })
        })
        .collect()
}

/// The per-task config: `base` with the task's system and derived seed.
pub fn derive_cfg(base: &RunConfig, system: &str, metric_id: &str) -> RunConfig {
    let mut cfg = base.clone();
    cfg.system = system.to_string();
    cfg.seed = task_seed(base.seed, system, metric_id);
    cfg
}

/// Execute `tasks` on a pool of `jobs` workers (0 = available parallelism).
///
/// Returns results **in input order** (unknown metric ids are skipped, as
/// in the sequential registry path) plus the run's [`ExecutionStats`].
/// Each task's config is derived from `base` via [`derive_cfg`].
pub fn execute(base: &RunConfig, tasks: &[Task], jobs: usize) -> (Vec<MetricResult>, ExecutionStats) {
    let pairs: Vec<(Task, RunConfig)> = tasks
        .iter()
        .map(|t| (t.clone(), derive_cfg(base, &t.system, t.metric_id)))
        .collect();
    execute_prepared(&pairs, jobs)
}

/// Execute explicit (task, per-task config) pairs on a pool of `jobs`
/// workers (0 = available parallelism).
///
/// This is the generalized entry point behind [`execute`]: callers that
/// vary more than the (system, metric) coordinates per task — e.g. the
/// scenario sweep, which also varies tenant count and quota per cell —
/// pre-derive one full [`RunConfig`] per task. Determinism contract: each
/// config (seed included) must be a pure function of its task's
/// coordinates, never of worker count or completion order; then results
/// are bit-identical at any job count. Results return **in input order**
/// (unknown metric ids are skipped).
pub fn execute_prepared(
    pairs: &[(Task, RunConfig)],
    jobs: usize,
) -> (Vec<MetricResult>, ExecutionStats) {
    let (slots, stats) = execute_prepared_indexed(pairs, jobs);
    (slots.into_iter().flatten().collect(), stats)
}

/// Like [`execute_prepared`], but results stay **aligned with input
/// indices**: slot `i` is `Some(result)` for `pairs[i]`, or `None` when
/// its metric id is unknown to the registry. Callers that must pair every
/// result back with its originating row (e.g. the regression engine
/// zipping re-runs against baseline rows) use this instead of relying on
/// length equality of the filtered result list.
pub fn execute_prepared_indexed(
    pairs: &[(Task, RunConfig)],
    jobs: usize,
) -> (Vec<Option<MetricResult>>, ExecutionStats) {
    let tasks: Vec<Task> = pairs.iter().map(|(t, _)| t.clone()).collect();
    execute_indexed_with(&tasks, jobs, |i, task| registry::run_metric(task.metric_id, &pairs[i].1))
}

/// The generic worker-pool core behind [`execute_prepared_indexed`]:
/// execute an arbitrary per-task function over `tasks` on a pool of
/// `jobs` workers (0 = available parallelism), returning results aligned
/// with input indices plus the run's [`ExecutionStats`].
///
/// `run(i, task)` produces the result for `tasks[i]`; returning `None`
/// leaves slot `i` empty and records no timing (the "unknown metric id"
/// convention of the metric paths). Callers that execute something other
/// than a registry metric per task — the `dynsim` dynamic-scenario
/// engine runs one whole scenario timeline per task — ride this directly.
/// The determinism contract is unchanged: `run` must be a pure function
/// of the task's coordinates (derive any seed from them), never of the
/// worker count or completion order.
pub fn execute_indexed_with<R, F>(
    tasks: &[Task],
    jobs: usize,
    run: F,
) -> (Vec<Option<R>>, ExecutionStats)
where
    R: Send,
    F: Fn(usize, &Task) -> Option<R> + Sync,
{
    let jobs = resolve_jobs(jobs).min(tasks.len().max(1));
    let t_start = Instant::now();
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<(R, TaskTiming)>>> =
        tasks.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for worker in 0..jobs {
            let cursor = &cursor;
            let slots = &slots;
            let run = &run;
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= tasks.len() {
                    break;
                }
                let task = &tasks[i];
                let t0 = Instant::now();
                if let Some(result) = run(i, task) {
                    let timing = TaskTiming {
                        system: task.system.clone(),
                        metric_id: task.metric_id,
                        wall_ns: t0.elapsed().as_nanos() as u64,
                        worker,
                    };
                    *slots[i].lock().unwrap() = Some((result, timing));
                }
            });
        }
    });
    let mut results: Vec<Option<R>> = Vec::with_capacity(tasks.len());
    let mut timings = Vec::with_capacity(tasks.len());
    for slot in slots {
        match slot.into_inner().unwrap() {
            Some((result, timing)) => {
                results.push(Some(result));
                timings.push(timing);
            }
            None => results.push(None),
        }
    }
    let stats =
        ExecutionStats { jobs, tasks: timings, wall_ns: t_start.elapsed().as_nanos() as u64 };
    (results, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cheap_ids() -> Vec<&'static str> {
        // Metrics with small fixed costs — keep executor unit tests fast.
        vec!["OH-009", "PCIE-001", "PCIE-004", "BW-003"]
    }

    #[test]
    fn preserves_input_order() {
        let base = RunConfig::quick("native");
        let tasks = task_matrix(&["native", "hami"], &cheap_ids());
        let (results, stats) = execute(&base, &tasks, 3);
        assert_eq!(results.len(), tasks.len());
        for (r, t) in results.iter().zip(&tasks) {
            assert_eq!(r.id, t.metric_id);
            assert_eq!(r.system, t.system);
        }
        assert_eq!(stats.tasks.len(), tasks.len());
        assert_eq!(stats.jobs, 3);
    }

    #[test]
    fn unknown_ids_skipped() {
        let base = RunConfig::quick("native");
        let tasks = vec![
            Task { system: "native".into(), metric_id: "OH-009" },
            Task { system: "native".into(), metric_id: "NOPE-1" },
            Task { system: "native".into(), metric_id: "PCIE-004" },
        ];
        let (results, stats) = execute(&base, &tasks, 2);
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].id, "OH-009");
        assert_eq!(results[1].id, "PCIE-004");
        assert_eq!(stats.tasks.len(), 2);
    }

    #[test]
    fn job_counts_agree_bitwise() {
        let base = RunConfig::quick("hami");
        let tasks = task_matrix(&["hami", "fcsp"], &cheap_ids());
        let (r1, s1) = execute(&base, &tasks, 1);
        let (r4, s4) = execute(&base, &tasks, 4);
        assert_eq!(s1.jobs, 1);
        assert_eq!(s4.jobs, 4);
        assert_eq!(r1.len(), r4.len());
        for (a, b) in r1.iter().zip(&r4) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "{}", a.id);
        }
    }

    #[test]
    fn execute_prepared_honours_per_task_cfg() {
        // Each task must run with exactly its own prepared config (not a
        // shared base): results match direct `run_metric` calls with the
        // same configs, bit for bit, at any job count.
        let base = RunConfig::quick("hami");
        let mut pairs: Vec<(Task, RunConfig)> = Vec::new();
        for (i, id) in cheap_ids().into_iter().enumerate() {
            let mut cfg = derive_cfg(&base, "hami", id);
            cfg.tenants = 2 + i as u32; // vary more than the seed per task
            cfg.seed = cfg.seed.wrapping_add(i as u64);
            pairs.push((Task { system: "hami".into(), metric_id: id }, cfg));
        }
        let (r1, _) = execute_prepared(&pairs, 1);
        let (r4, _) = execute_prepared(&pairs, 4);
        assert_eq!(r1.len(), pairs.len());
        for ((task, cfg), (a, b)) in pairs.iter().zip(r1.iter().zip(&r4)) {
            let direct = registry::run_metric(task.metric_id, cfg).unwrap();
            assert_eq!(a.value.to_bits(), direct.value.to_bits(), "{}", task.metric_id);
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "{}", task.metric_id);
        }
    }

    #[test]
    fn indexed_results_keep_slots_for_unknown_ids() {
        let base = RunConfig::quick("native");
        let pairs: Vec<(Task, RunConfig)> = vec![
            ("OH-009", derive_cfg(&base, "native", "OH-009")),
            ("NOPE-1", derive_cfg(&base, "native", "NOPE-1")),
            ("PCIE-004", derive_cfg(&base, "native", "PCIE-004")),
        ]
        .into_iter()
        .map(|(id, cfg)| (Task { system: "native".into(), metric_id: id }, cfg))
        .collect();
        let (slots, stats) = execute_prepared_indexed(&pairs, 2);
        assert_eq!(slots.len(), 3);
        assert_eq!(slots[0].as_ref().unwrap().id, "OH-009");
        assert!(slots[1].is_none());
        assert_eq!(slots[2].as_ref().unwrap().id, "PCIE-004");
        assert_eq!(stats.tasks.len(), 2);
    }

    #[test]
    fn generic_core_runs_arbitrary_task_functions() {
        // execute_indexed_with is the shared pool core: results align with
        // input indices, None slots record no timing, and output order is
        // independent of the worker count.
        let tasks: Vec<Task> = (0..7)
            .map(|i| Task { system: format!("sys{i}"), metric_id: "X-1" })
            .collect();
        let run = |i: usize, task: &Task| {
            if i == 3 {
                None
            } else {
                Some(format!("{}#{}", task.system, i))
            }
        };
        let (r1, s1) = execute_indexed_with(&tasks, 1, run);
        let (r4, s4) = execute_indexed_with(&tasks, 4, run);
        assert_eq!(r1, r4);
        assert_eq!(r1.len(), 7);
        assert!(r1[3].is_none());
        assert_eq!(r1[2].as_deref(), Some("sys2#2"));
        assert_eq!(s1.tasks.len(), 6);
        assert_eq!(s4.tasks.len(), 6);
    }

    #[test]
    fn derived_cfg_changes_seed_and_system() {
        let base = RunConfig::quick("native");
        let a = derive_cfg(&base, "hami", "OH-001");
        let b = derive_cfg(&base, "hami", "OH-002");
        assert_eq!(a.system, "hami");
        assert_ne!(a.seed, b.seed);
        assert_eq!(a.iterations, base.iterations);
    }

    #[test]
    fn stats_aggregates() {
        let base = RunConfig::quick("native");
        let tasks = task_matrix(&["native"], &cheap_ids());
        let (_, stats) = execute(&base, &tasks, 2);
        assert!(stats.wall_ns > 0);
        assert!(stats.total_task_ns() >= stats.max_task_ns());
        assert!(stats.speedup_estimate() > 0.0);
    }

    #[test]
    fn resolve_jobs_auto_positive() {
        assert!(resolve_jobs(0) >= 1);
        assert_eq!(resolve_jobs(7), 7);
    }

    #[test]
    fn empty_matrix() {
        let base = RunConfig::quick("native");
        let (results, stats) = execute(&base, &[], 4);
        assert!(results.is_empty());
        assert!(stats.tasks.is_empty());
    }
}
