//! Parallel, sharded execution of the (system × metric) task matrix.
//!
//! The full Table-8 evaluation (4 systems × 56 metrics = 224 tasks) used to
//! run strictly sequentially. Every metric is an independent pure function
//! of its [`RunConfig`] — each builds its own simulated device — so the
//! matrix shards perfectly across a worker pool:
//!
//! 1. The caller describes the matrix as a flat `Vec<Task>` in the desired
//!    output (Table-8) order.
//! 2. `--jobs N` scoped threads (default: available parallelism) pull task
//!    indices from a shared atomic cursor — classic work stealing by
//!    sharded index, no channels, no unsafe.
//! 3. Each task derives its own seed with [`task_seed`]`(cfg.seed, system,
//!    metric_id)` — a pure function of the run seed and the task
//!    coordinates — so the numbers are **bit-identical regardless of worker
//!    count or completion order** (see `rust/tests/determinism.rs`).
//! 4. Results land in per-index slots and are re-assembled in input order;
//!    wall-clock and per-task timings are recorded in [`ExecutionStats`]
//!    and surfaced by the JSON/CSV reporters.
//!
//! Two pool shapes share that contract. The free functions above spin up
//! a *scoped* pool per call — workers live exactly as long as one task
//! matrix, which is all a one-shot CLI invocation needs. [`WorkerPool`]
//! keeps the same workers alive across many matrices: the `gvbench
//! serve` daemon owns one pool for its whole lifetime and runs every
//! queued job's matrix on it ([`Backend`] selects the shape per call).
//! Within a batch the claiming discipline is identical — an atomic
//! cursor over input indices — so results are bit-identical between the
//! two shapes at any worker count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::metrics::{registry, MetricResult, RunConfig};
use crate::util::rng::task_seed;

/// One (system, metric) cell of the evaluation matrix.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Task {
    /// Backend key (`native` / `hami` / `fcsp` / `mig` / `timeslice`).
    pub system: String,
    /// Metric id from the Table-8 taxonomy (e.g. `OH-001`).
    pub metric_id: &'static str,
}

/// Wall-clock timing of one executed task.
#[derive(Clone, Debug)]
pub struct TaskTiming {
    pub system: String,
    pub metric_id: &'static str,
    /// Host wall-clock spent executing the task, ns.
    pub wall_ns: u64,
    /// Task start, ns after the matrix started (host wall-clock offset;
    /// the span renderer `obs::chrome` places the task on its worker's
    /// lane with it).
    pub start_ns: u64,
    /// Worker index (0-based) that ran the task.
    pub worker: usize,
}

/// Aggregate statistics for one executor invocation.
#[derive(Clone, Debug, Default)]
pub struct ExecutionStats {
    /// Worker count actually used.
    pub jobs: usize,
    /// Per-task timings, in output (Table-8) order.
    pub tasks: Vec<TaskTiming>,
    /// End-to-end wall-clock of the whole matrix, ns.
    pub wall_ns: u64,
}

impl ExecutionStats {
    /// Sum of per-task wall-clock (the serial-equivalent cost), ns.
    pub fn total_task_ns(&self) -> u64 {
        self.tasks.iter().map(|t| t.wall_ns).sum()
    }

    /// Longest single task, ns (the parallel-speedup floor).
    pub fn max_task_ns(&self) -> u64 {
        self.tasks.iter().map(|t| t.wall_ns).max().unwrap_or(0)
    }

    /// Achieved busy/wall ratio — ≈ the effective parallel speedup over a
    /// serial run of the same tasks.
    pub fn speedup_estimate(&self) -> f64 {
        if self.wall_ns == 0 {
            return 1.0;
        }
        self.total_task_ns() as f64 / self.wall_ns as f64
    }

    /// Worker capacity the matrix left idle, ns: `jobs × wall − busy`.
    /// Nonzero whenever stragglers at the batch tail (or a matrix smaller
    /// than the pool) starve some workers — the per-job worker-side idle
    /// figure the serve daemon reports next to its scheduler idle time.
    pub fn worker_idle_ns(&self) -> u64 {
        (self.jobs as u64)
            .saturating_mul(self.wall_ns)
            .saturating_sub(self.total_task_ns())
    }
}

/// Resolve a requested job count: 0 means "available parallelism".
pub fn resolve_jobs(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Build the full matrix for `systems` × `metric_ids`, system-major (all of
/// system 0's metrics in Table-8 order, then system 1, …).
pub fn task_matrix(systems: &[&str], metric_ids: &[&'static str]) -> Vec<Task> {
    systems
        .iter()
        .flat_map(|s| {
            metric_ids.iter().map(move |id| Task { system: s.to_string(), metric_id: *id })
        })
        .collect()
}

/// The per-task config: `base` with the task's system and derived seed.
pub fn derive_cfg(base: &RunConfig, system: &str, metric_id: &str) -> RunConfig {
    let mut cfg = base.clone();
    cfg.system = system.to_string();
    cfg.seed = task_seed(base.seed, system, metric_id);
    cfg
}

/// Execute `tasks` on a pool of `jobs` workers (0 = available parallelism).
///
/// Returns results **in input order** (unknown metric ids are skipped, as
/// in the sequential registry path) plus the run's [`ExecutionStats`].
/// Each task's config is derived from `base` via [`derive_cfg`].
pub fn execute(base: &RunConfig, tasks: &[Task], jobs: usize) -> (Vec<MetricResult>, ExecutionStats) {
    let pairs: Vec<(Task, RunConfig)> = tasks
        .iter()
        .map(|t| (t.clone(), derive_cfg(base, &t.system, t.metric_id)))
        .collect();
    execute_prepared(&pairs, jobs)
}

/// Execute explicit (task, per-task config) pairs on a pool of `jobs`
/// workers (0 = available parallelism).
///
/// This is the generalized entry point behind [`execute`]: callers that
/// vary more than the (system, metric) coordinates per task — e.g. the
/// scenario sweep, which also varies tenant count and quota per cell —
/// pre-derive one full [`RunConfig`] per task. Determinism contract: each
/// config (seed included) must be a pure function of its task's
/// coordinates, never of worker count or completion order; then results
/// are bit-identical at any job count. Results return **in input order**
/// (unknown metric ids are skipped).
pub fn execute_prepared(
    pairs: &[(Task, RunConfig)],
    jobs: usize,
) -> (Vec<MetricResult>, ExecutionStats) {
    let (slots, stats) = execute_prepared_indexed(pairs, jobs);
    (slots.into_iter().flatten().collect(), stats)
}

/// Like [`execute_prepared`], but results stay **aligned with input
/// indices**: slot `i` is `Some(result)` for `pairs[i]`, or `None` when
/// its metric id is unknown to the registry. Callers that must pair every
/// result back with its originating row (e.g. the regression engine
/// zipping re-runs against baseline rows) use this instead of relying on
/// length equality of the filtered result list.
pub fn execute_prepared_indexed(
    pairs: &[(Task, RunConfig)],
    jobs: usize,
) -> (Vec<Option<MetricResult>>, ExecutionStats) {
    let tasks: Vec<Task> = pairs.iter().map(|(t, _)| t.clone()).collect();
    execute_indexed_with(&tasks, jobs, |i, task| registry::run_metric(task.metric_id, &pairs[i].1))
}

/// The generic worker-pool core behind [`execute_prepared_indexed`]:
/// execute an arbitrary per-task function over `tasks` on a pool of
/// `jobs` workers (0 = available parallelism), returning results aligned
/// with input indices plus the run's [`ExecutionStats`].
///
/// `run(i, task)` produces the result for `tasks[i]`; returning `None`
/// leaves slot `i` empty and records no timing (the "unknown metric id"
/// convention of the metric paths). Callers that execute something other
/// than a registry metric per task — the `dynsim` dynamic-scenario
/// engine runs one whole scenario timeline per task — ride this directly.
/// The determinism contract is unchanged: `run` must be a pure function
/// of the task's coordinates (derive any seed from them), never of the
/// worker count or completion order.
pub fn execute_indexed_with<R, F>(
    tasks: &[Task],
    jobs: usize,
    run: F,
) -> (Vec<Option<R>>, ExecutionStats)
where
    R: Send,
    F: Fn(usize, &Task) -> Option<R> + Sync,
{
    let jobs = resolve_jobs(jobs).min(tasks.len().max(1));
    let t_start = Instant::now();
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<(R, TaskTiming)>>> =
        tasks.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for worker in 0..jobs {
            let cursor = &cursor;
            let slots = &slots;
            let run = &run;
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= tasks.len() {
                    break;
                }
                let task = &tasks[i];
                let start_ns = t_start.elapsed().as_nanos() as u64;
                let t0 = Instant::now();
                if let Some(result) = run(i, task) {
                    let timing = TaskTiming {
                        system: task.system.clone(),
                        metric_id: task.metric_id,
                        wall_ns: t0.elapsed().as_nanos() as u64,
                        start_ns,
                        worker,
                    };
                    *slots[i].lock().unwrap() = Some((result, timing));
                }
            });
        }
    });
    let mut results: Vec<Option<R>> = Vec::with_capacity(tasks.len());
    let mut timings = Vec::with_capacity(tasks.len());
    for slot in slots {
        match slot.into_inner().unwrap() {
            Some((result, timing)) => {
                results.push(Some(result));
                timings.push(timing);
            }
            None => results.push(None),
        }
    }
    let stats =
        ExecutionStats { jobs, tasks: timings, wall_ns: t_start.elapsed().as_nanos() as u64 };
    (results, stats)
}

/// One completed task's progress note, as seen by an [`Observer`]:
/// which input slot finished, out of how many, plus a representative
/// value for incremental-result streaming (NaN when the task's result is
/// not a single scalar, e.g. a whole dynamics timeline).
#[derive(Clone, Debug)]
pub struct TaskDone {
    /// Input index of the completed task.
    pub index: usize,
    /// Total tasks in the matrix.
    pub total: usize,
    pub system: String,
    /// Metric id / scenario key / fleet-cell label of the task.
    pub label: String,
    pub value: f64,
}

/// Per-task completion callback. Called from worker threads in
/// *completion* order (not input order) — the serve daemon turns these
/// into `task_completed` lifecycle events on a job's stream. Observers
/// must not assume any ordering and must never influence results (the
/// determinism contract is on the task functions, not the observer).
pub type Observer = Arc<dyn Fn(TaskDone) + Send + Sync>;

/// Where a task matrix executes: a scoped per-call pool of N workers
/// (0 = available parallelism; the one-shot CLI path) or a persistent
/// [`WorkerPool`] shared across jobs (the serve-daemon path). Results
/// are bit-identical between the two at any worker count.
pub enum Backend<'a> {
    Scoped(usize),
    Pool(&'a WorkerPool),
}

/// [`execute_indexed_with`] generalized over the pool shape: run the
/// matrix on `exec`, scoped threads or a persistent pool alike. The
/// `'static` bounds exist because persistent workers outlive the call —
/// callers hand the task list over as an `Arc` and move owned state into
/// `run`.
pub fn execute_indexed_on<R, F>(
    exec: &Backend<'_>,
    tasks: Arc<Vec<Task>>,
    run: F,
) -> (Vec<Option<R>>, ExecutionStats)
where
    R: Send + 'static,
    F: Fn(usize, &Task) -> Option<R> + Send + Sync + 'static,
{
    match exec {
        Backend::Scoped(jobs) => execute_indexed_with(&tasks, *jobs, run),
        Backend::Pool(pool) => pool.execute_indexed(tasks, run),
    }
}

/// One type-erased task matrix queued on a [`WorkerPool`].
struct PoolBatch {
    len: usize,
    cursor: AtomicUsize,
    /// Tasks claimed but not yet finished; the last finisher clears the
    /// batch slot and wakes the submitter.
    pending: AtomicUsize,
    run: Box<dyn Fn(usize, usize) + Send + Sync>,
}

struct PoolState {
    batch: Option<Arc<PoolBatch>>,
    /// Bumped per batch so a worker that drained the cursor does not
    /// re-claim the same (still-posted) batch while stragglers finish.
    generation: u64,
    shutdown: bool,
}

/// A persistent worker pool: the same OS threads execute many task
/// matrices over the pool's lifetime. One matrix runs at a time
/// (submissions serialize); within a matrix, workers claim input indices
/// from an atomic cursor exactly like the scoped pool, so the
/// determinism contract — and the bit-identical-at-any-worker-count
/// guarantee — is unchanged. Dropping the pool (or calling
/// [`WorkerPool::shutdown`]) joins every worker, so no threads outlive
/// the owner.
pub struct WorkerPool {
    jobs: usize,
    state: Arc<(Mutex<PoolState>, Condvar)>,
    /// Serializes concurrent submitters (the daemon has one scheduler,
    /// but the pool does not rely on that).
    submit: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `jobs` workers (0 = available parallelism).
    pub fn new(jobs: usize) -> WorkerPool {
        let jobs = resolve_jobs(jobs);
        let state = Arc::new((
            Mutex::new(PoolState { batch: None, generation: 0, shutdown: false }),
            Condvar::new(),
        ));
        let handles = (0..jobs)
            .map(|worker| {
                let state = Arc::clone(&state);
                std::thread::spawn(move || Self::worker_loop(&state, worker))
            })
            .collect();
        WorkerPool { jobs, state, submit: Mutex::new(()), handles }
    }

    /// Worker count of the pool.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    fn worker_loop(state: &(Mutex<PoolState>, Condvar), worker: usize) {
        let (lock, cv) = state;
        let mut seen_generation = 0u64;
        loop {
            let batch = {
                let mut st = lock.lock().unwrap();
                loop {
                    if let Some(b) = &st.batch {
                        if st.generation != seen_generation {
                            seen_generation = st.generation;
                            break Arc::clone(b);
                        }
                    }
                    if st.shutdown {
                        return;
                    }
                    st = cv.wait(st).unwrap();
                }
            };
            loop {
                let i = batch.cursor.fetch_add(1, Ordering::Relaxed);
                if i >= batch.len {
                    break;
                }
                (batch.run)(i, worker);
                if batch.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                    let mut st = lock.lock().unwrap();
                    st.batch = None;
                    cv.notify_all();
                }
            }
        }
    }

    /// Run one type-erased matrix to completion: `run(i, worker)` is
    /// called exactly once for every `i < len`, from pool workers.
    /// Blocks until every task finished.
    fn run_batch(&self, len: usize, run: Box<dyn Fn(usize, usize) + Send + Sync>) {
        if len == 0 {
            return;
        }
        let _serialize = self.submit.lock().unwrap();
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        debug_assert!(st.batch.is_none(), "submissions are serialized");
        st.generation += 1;
        st.batch = Some(Arc::new(PoolBatch {
            len,
            cursor: AtomicUsize::new(0),
            pending: AtomicUsize::new(len),
            run,
        }));
        cv.notify_all();
        while st.batch.is_some() {
            st = cv.wait(st).unwrap();
        }
    }

    /// [`execute_indexed_with`] on the persistent pool: results aligned
    /// with input indices, `None` slots record no timing, bit-identical
    /// to the scoped path.
    pub fn execute_indexed<R, F>(
        &self,
        tasks: Arc<Vec<Task>>,
        run: F,
    ) -> (Vec<Option<R>>, ExecutionStats)
    where
        R: Send + 'static,
        F: Fn(usize, &Task) -> Option<R> + Send + Sync + 'static,
    {
        let t_start = Instant::now();
        let slots: Arc<Vec<Mutex<Option<(R, TaskTiming)>>>> =
            Arc::new(tasks.iter().map(|_| Mutex::new(None)).collect());
        {
            let slots = Arc::clone(&slots);
            let batch_tasks = Arc::clone(&tasks);
            self.run_batch(
                tasks.len(),
                Box::new(move |i, worker| {
                    let task = &batch_tasks[i];
                    let start_ns = t_start.elapsed().as_nanos() as u64;
                    let t0 = Instant::now();
                    if let Some(result) = run(i, task) {
                        let timing = TaskTiming {
                            system: task.system.clone(),
                            metric_id: task.metric_id,
                            wall_ns: t0.elapsed().as_nanos() as u64,
                            start_ns,
                            worker,
                        };
                        *slots[i].lock().unwrap() = Some((result, timing));
                    }
                }),
            );
        }
        // Straggler workers may hold their batch Arc (and thus the slot
        // Arc) a beat longer than run_batch; drain through the shared
        // handle instead of unwrapping it.
        let mut results: Vec<Option<R>> = Vec::with_capacity(tasks.len());
        let mut timings = Vec::with_capacity(tasks.len());
        for slot in slots.iter() {
            match slot.lock().unwrap().take() {
                Some((result, timing)) => {
                    results.push(Some(result));
                    timings.push(timing);
                }
                None => results.push(None),
            }
        }
        let stats = ExecutionStats {
            jobs: self.jobs,
            tasks: timings,
            wall_ns: t_start.elapsed().as_nanos() as u64,
        };
        (results, stats)
    }

    /// Stop accepting batches and join every worker. Idempotent; also
    /// runs on drop.
    pub fn shutdown(&mut self) {
        {
            let (lock, cv) = &*self.state;
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cheap_ids() -> Vec<&'static str> {
        // Metrics with small fixed costs — keep executor unit tests fast.
        vec!["OH-009", "PCIE-001", "PCIE-004", "BW-003"]
    }

    #[test]
    fn preserves_input_order() {
        let base = RunConfig::quick("native");
        let tasks = task_matrix(&["native", "hami"], &cheap_ids());
        let (results, stats) = execute(&base, &tasks, 3);
        assert_eq!(results.len(), tasks.len());
        for (r, t) in results.iter().zip(&tasks) {
            assert_eq!(r.id, t.metric_id);
            assert_eq!(r.system, t.system);
        }
        assert_eq!(stats.tasks.len(), tasks.len());
        assert_eq!(stats.jobs, 3);
    }

    #[test]
    fn unknown_ids_skipped() {
        let base = RunConfig::quick("native");
        let tasks = vec![
            Task { system: "native".into(), metric_id: "OH-009" },
            Task { system: "native".into(), metric_id: "NOPE-1" },
            Task { system: "native".into(), metric_id: "PCIE-004" },
        ];
        let (results, stats) = execute(&base, &tasks, 2);
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].id, "OH-009");
        assert_eq!(results[1].id, "PCIE-004");
        assert_eq!(stats.tasks.len(), 2);
    }

    #[test]
    fn job_counts_agree_bitwise() {
        let base = RunConfig::quick("hami");
        let tasks = task_matrix(&["hami", "fcsp"], &cheap_ids());
        let (r1, s1) = execute(&base, &tasks, 1);
        let (r4, s4) = execute(&base, &tasks, 4);
        assert_eq!(s1.jobs, 1);
        assert_eq!(s4.jobs, 4);
        assert_eq!(r1.len(), r4.len());
        for (a, b) in r1.iter().zip(&r4) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "{}", a.id);
        }
    }

    #[test]
    fn execute_prepared_honours_per_task_cfg() {
        // Each task must run with exactly its own prepared config (not a
        // shared base): results match direct `run_metric` calls with the
        // same configs, bit for bit, at any job count.
        let base = RunConfig::quick("hami");
        let mut pairs: Vec<(Task, RunConfig)> = Vec::new();
        for (i, id) in cheap_ids().into_iter().enumerate() {
            let mut cfg = derive_cfg(&base, "hami", id);
            cfg.tenants = 2 + i as u32; // vary more than the seed per task
            cfg.seed = cfg.seed.wrapping_add(i as u64);
            pairs.push((Task { system: "hami".into(), metric_id: id }, cfg));
        }
        let (r1, _) = execute_prepared(&pairs, 1);
        let (r4, _) = execute_prepared(&pairs, 4);
        assert_eq!(r1.len(), pairs.len());
        for ((task, cfg), (a, b)) in pairs.iter().zip(r1.iter().zip(&r4)) {
            let direct = registry::run_metric(task.metric_id, cfg).unwrap();
            assert_eq!(a.value.to_bits(), direct.value.to_bits(), "{}", task.metric_id);
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "{}", task.metric_id);
        }
    }

    #[test]
    fn indexed_results_keep_slots_for_unknown_ids() {
        let base = RunConfig::quick("native");
        let pairs: Vec<(Task, RunConfig)> = vec![
            ("OH-009", derive_cfg(&base, "native", "OH-009")),
            ("NOPE-1", derive_cfg(&base, "native", "NOPE-1")),
            ("PCIE-004", derive_cfg(&base, "native", "PCIE-004")),
        ]
        .into_iter()
        .map(|(id, cfg)| (Task { system: "native".into(), metric_id: id }, cfg))
        .collect();
        let (slots, stats) = execute_prepared_indexed(&pairs, 2);
        assert_eq!(slots.len(), 3);
        assert_eq!(slots[0].as_ref().unwrap().id, "OH-009");
        assert!(slots[1].is_none());
        assert_eq!(slots[2].as_ref().unwrap().id, "PCIE-004");
        assert_eq!(stats.tasks.len(), 2);
    }

    #[test]
    fn generic_core_runs_arbitrary_task_functions() {
        // execute_indexed_with is the shared pool core: results align with
        // input indices, None slots record no timing, and output order is
        // independent of the worker count.
        let tasks: Vec<Task> = (0..7)
            .map(|i| Task { system: format!("sys{i}"), metric_id: "X-1" })
            .collect();
        let run = |i: usize, task: &Task| {
            if i == 3 {
                None
            } else {
                Some(format!("{}#{}", task.system, i))
            }
        };
        let (r1, s1) = execute_indexed_with(&tasks, 1, run);
        let (r4, s4) = execute_indexed_with(&tasks, 4, run);
        assert_eq!(r1, r4);
        assert_eq!(r1.len(), 7);
        assert!(r1[3].is_none());
        assert_eq!(r1[2].as_deref(), Some("sys2#2"));
        assert_eq!(s1.tasks.len(), 6);
        assert_eq!(s4.tasks.len(), 6);
    }

    #[test]
    fn derived_cfg_changes_seed_and_system() {
        let base = RunConfig::quick("native");
        let a = derive_cfg(&base, "hami", "OH-001");
        let b = derive_cfg(&base, "hami", "OH-002");
        assert_eq!(a.system, "hami");
        assert_ne!(a.seed, b.seed);
        assert_eq!(a.iterations, base.iterations);
    }

    #[test]
    fn stats_aggregates() {
        let base = RunConfig::quick("native");
        let tasks = task_matrix(&["native"], &cheap_ids());
        let (_, stats) = execute(&base, &tasks, 2);
        assert!(stats.wall_ns > 0);
        assert!(stats.total_task_ns() >= stats.max_task_ns());
        assert!(stats.speedup_estimate() > 0.0);
    }

    #[test]
    fn resolve_jobs_auto_positive() {
        assert!(resolve_jobs(0) >= 1);
        assert_eq!(resolve_jobs(7), 7);
    }

    #[test]
    fn empty_matrix() {
        let base = RunConfig::quick("native");
        let (results, stats) = execute(&base, &[], 4);
        assert!(results.is_empty());
        assert!(stats.tasks.is_empty());
    }

    #[test]
    fn pool_matches_scoped_path_bitwise() {
        let base = RunConfig::quick("hami");
        let pairs: Vec<(Task, RunConfig)> = task_matrix(&["hami", "fcsp"], &cheap_ids())
            .into_iter()
            .map(|t| {
                let cfg = derive_cfg(&base, &t.system, t.metric_id);
                (t, cfg)
            })
            .collect();
        let (scoped, _) = execute_prepared_indexed(&pairs, 2);
        let pool = WorkerPool::new(3);
        let tasks: Arc<Vec<Task>> = Arc::new(pairs.iter().map(|(t, _)| t.clone()).collect());
        let shared = Arc::new(pairs);
        let run = {
            let shared = Arc::clone(&shared);
            move |i: usize, task: &Task| registry::run_metric(task.metric_id, &shared[i].1)
        };
        let (pooled, stats) = execute_indexed_on(&Backend::Pool(&pool), tasks, run);
        assert_eq!(stats.jobs, 3);
        assert_eq!(stats.tasks.len(), pooled.len());
        assert_eq!(scoped.len(), pooled.len());
        for (a, b) in scoped.iter().zip(&pooled) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.id, b.id);
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "{}", a.id);
        }
    }

    #[test]
    fn pool_survives_many_batches_and_joins_cleanly() {
        let mut pool = WorkerPool::new(2);
        for round in 0..5u64 {
            let tasks: Arc<Vec<Task>> = Arc::new(
                (0..7).map(|i| Task { system: format!("s{i}"), metric_id: "X-1" }).collect(),
            );
            let run = move |i: usize, task: &Task| {
                if i == 3 {
                    None
                } else {
                    Some(format!("{}#{round}", task.system))
                }
            };
            let (slots, stats) = pool.execute_indexed(tasks, run);
            assert_eq!(slots.len(), 7);
            assert!(slots[3].is_none());
            assert_eq!(slots[2].as_deref(), Some(format!("s2#{round}").as_str()));
            assert_eq!(stats.tasks.len(), 6);
            assert_eq!(stats.jobs, 2);
        }
        pool.shutdown();
        pool.shutdown(); // idempotent
    }

    #[test]
    fn pool_empty_batch_is_a_noop() {
        let pool = WorkerPool::new(2);
        let (slots, stats) =
            pool.execute_indexed(Arc::new(Vec::new()), |_i, _t: &Task| Some(1u8));
        assert!(slots.is_empty());
        assert!(stats.tasks.is_empty());
    }

    #[test]
    fn worker_idle_accounts_capacity() {
        let stats = ExecutionStats {
            jobs: 4,
            tasks: vec![TaskTiming {
                system: "native".into(),
                metric_id: "OH-009",
                wall_ns: 100,
                start_ns: 0,
                worker: 0,
            }],
            wall_ns: 50,
        };
        assert_eq!(stats.worker_idle_ns(), 4 * 50 - 100);
        // Saturates instead of underflowing on timer jitter.
        let tight = ExecutionStats { jobs: 1, tasks: stats.tasks.clone(), wall_ns: 50 };
        assert_eq!(tight.worker_idle_ns(), 0);
    }
}
