//! Workload generators: request arrival processes and kernel mixes.
//!
//! [`RequestGenerator`] produces Poisson arrivals with LLM-serving-shaped
//! (log-uniform) prompt/generation lengths. Its primary consumer is the
//! `dynsim` virtual-time dynamic-scenario engine
//! ([`crate::dynsim::engine`]), which drives one generator per simulated
//! tenant — rescaling `rate_hz` for burst phases — and turns each
//! [`Request`] into its prefill/decode kernel pair
//! ([`Request::prefill_kernel`] / [`Request::decode_kernel`]). The
//! examples and the end-to-end OH-010-style runs use the same generators
//! for open-loop load.
//!
//! [`TrainingGenerator`] is the training-side counterpart: paced (not
//! Poisson) optimizer steps, each a forward/backward/optimizer kernel
//! triple ([`TrainStep::forward_kernel`] / [`TrainStep::backward_kernel`]
//! / [`TrainStep::optimizer_kernel`]) with a gradient allreduce every
//! `accum_steps` micro-batches ([`TrainStep::grad_sync`]) routed through
//! the collective model the NCCL tasks use. The dynsim engine schedules
//! train steps on the same event queue as inference arrivals, which is
//! what makes mixed train+infer populations replayable.

use crate::simgpu::kernel::KernelDesc;
use crate::util::Rng;

/// A generated inference request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Arrival time offset from the previous request, ns.
    pub inter_arrival_ns: f64,
    /// Prompt length (tokens).
    pub prompt_len: u64,
    /// Tokens to generate.
    pub gen_len: u64,
    /// Batch-able (shares a decode step with others).
    pub batchable: bool,
}

impl Request {
    /// The request's prefill phase: one fused attention pass over the
    /// prompt (bf16) — compute scales with `prompt_len²`.
    pub fn prefill_kernel(&self) -> KernelDesc {
        KernelDesc::attention(1, self.prompt_len.max(1), 64, true)
    }

    /// The request's decode phase as one fused kernel covering all
    /// generated tokens: the classic weight-streaming-bound regime (a
    /// ~25M-param layer group's bf16 weights re-read once per token), so
    /// service time scales linearly with `gen_len`.
    pub fn decode_kernel(&self) -> KernelDesc {
        let params = 25_000_000f64;
        let tokens = self.gen_len.max(1) as f64;
        KernelDesc {
            flops: 2.0 * params * tokens,
            bytes: params * 2.0 * tokens,
            half_precision: true,
            occupancy: 1.0,
        }
    }
}

/// A rate-independent request drawn ahead of time: everything
/// [`RequestGenerator::next_request`] samples except the arrival-rate
/// scaling. The dynsim engine draws these in batches per tenant and
/// realizes each against the rate current at consumption
/// ([`RequestGenerator::realize`]), which is bit-identical to a direct
/// `next_request` call at the same point — the unit-rate exponential
/// divides by the rate at realization, and `x / 1.0` is exact — while
/// amortizing generator-call overhead across the batch.
#[derive(Clone, Copy, Debug)]
pub struct ProtoRequest {
    /// Unit-rate exponential inter-arrival draw (seconds at 1 Hz).
    pub exp_unit: f64,
    pub prompt_len: u64,
    pub gen_len: u64,
    pub batchable: bool,
}

/// Poisson request generator with LLM-serving-shaped length distributions.
#[derive(Clone, Debug)]
pub struct RequestGenerator {
    rng: Rng,
    /// Mean arrival rate, requests/second.
    pub rate_hz: f64,
    pub max_prompt: u64,
    pub max_gen: u64,
}

/// Log-uniform length sample in `[2^lo_exp, max]`, clamping the exponent
/// range so it never inverts when `max < 2^lo_exp` (small caps collapse
/// to the constant `max` instead of sampling outside the bounds).
fn log_uniform_len(rng: &mut Rng, lo_exp: f64, max: u64) -> u64 {
    let hi = (max.max(1) as f64).log2();
    let lo = lo_exp.min(hi);
    ((2f64).powf(rng.f64_range(lo, hi)) as u64).clamp(1, max.max(1))
}

impl RequestGenerator {
    pub fn new(seed: u64, rate_hz: f64) -> RequestGenerator {
        RequestGenerator { rng: Rng::new(seed), rate_hz, max_prompt: 2048, max_gen: 256 }
    }

    /// Builder: override the prompt/generation length caps (the dynsim
    /// engine uses serving-scaled caps so scenario timelines stay cheap).
    pub fn with_lengths(mut self, max_prompt: u64, max_gen: u64) -> RequestGenerator {
        self.max_prompt = max_prompt;
        self.max_gen = max_gen;
        self
    }

    pub fn next_request(&mut self) -> Request {
        let proto = self.next_proto();
        self.realize(proto)
    }

    /// Draw the stream's next request with the arrival-rate scaling left
    /// out. Consumes exactly the draws `next_request` would (in the same
    /// order), so interleaving proto and direct draws keeps the stream
    /// aligned.
    pub fn next_proto(&mut self) -> ProtoRequest {
        let exp_unit = self.rng.exponential(1.0);
        // Prompt lengths are long-tailed; use a simple log-uniform.
        let prompt = log_uniform_len(&mut self.rng, 5.0, self.max_prompt);
        let gen = log_uniform_len(&mut self.rng, 3.0, self.max_gen);
        ProtoRequest {
            exp_unit,
            prompt_len: prompt,
            gen_len: gen,
            batchable: self.rng.chance(0.8),
        }
    }

    /// Realize a proto-request against the *current* `rate_hz`.
    /// Bit-identical to the request `next_request` would have produced
    /// from the same draws at this rate: `exponential(r)` divides the
    /// unit-rate draw by `r`, so `(exp_unit / r) * 1e9` reproduces
    /// `exponential(r) * 1e9` exactly.
    pub fn realize(&self, proto: ProtoRequest) -> Request {
        Request {
            inter_arrival_ns: proto.exp_unit / self.rate_hz * 1e9,
            prompt_len: proto.prompt_len,
            gen_len: proto.gen_len,
            batchable: proto.batchable,
        }
    }

    /// Generate a trace of `n` requests.
    pub fn trace(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

/// Parameter count of the simulated training model's resident layer
/// group — matched to the ~25M-param group the decode model streams, so
/// train and infer tenants contend for the same device at comparable
/// per-op scales.
const TRAIN_PARAMS: f64 = 25_000_000.0;

/// One training optimizer step: a forward/backward/optimizer kernel
/// triple over `batch_tokens`, with a gradient allreduce when the
/// accumulation boundary is reached.
#[derive(Clone, Copy, Debug)]
pub struct TrainStep {
    /// Offset from the previous step, ns (paced, lightly jittered — a
    /// training loop is a closed loop, not a Poisson process).
    pub inter_arrival_ns: f64,
    /// Tokens in this micro-batch.
    pub batch_tokens: u64,
    /// Whether this step closes a gradient-accumulation round and
    /// therefore performs the allreduce + optimizer update.
    pub grad_sync: bool,
}

impl TrainStep {
    /// Forward pass: compute-bound bf16 GEMM work, 2 FLOPs per
    /// parameter per token, activations written once.
    pub fn forward_kernel(&self) -> KernelDesc {
        let tokens = self.batch_tokens.max(1) as f64;
        KernelDesc {
            flops: 2.0 * TRAIN_PARAMS * tokens,
            bytes: TRAIN_PARAMS * 2.0,
            half_precision: true,
            occupancy: 1.0,
        }
    }

    /// Backward pass: the classic 2x-forward FLOP count (grad w.r.t.
    /// activations + grad w.r.t. weights).
    pub fn backward_kernel(&self) -> KernelDesc {
        let tokens = self.batch_tokens.max(1) as f64;
        KernelDesc {
            flops: 4.0 * TRAIN_PARAMS * tokens,
            bytes: TRAIN_PARAMS * 2.0,
            half_precision: true,
            occupancy: 1.0,
        }
    }

    /// Optimizer update: memory-bound fp32 streaming over params +
    /// gradients + moment state (Adam-style ~12 bytes/param), trivial
    /// compute.
    pub fn optimizer_kernel(&self) -> KernelDesc {
        KernelDesc {
            flops: 4.0 * TRAIN_PARAMS,
            bytes: TRAIN_PARAMS * 12.0,
            half_precision: false,
            occupancy: 1.0,
        }
    }

    /// Gradient payload of the allreduce on `grad_sync` steps: one bf16
    /// gradient per parameter.
    pub fn allreduce_bytes(&self) -> u64 {
        (TRAIN_PARAMS * 2.0) as u64
    }
}

/// Paced training-step generator: the closed-loop counterpart of
/// [`RequestGenerator`]. `rate_hz` is optimizer steps per second; steps
/// arrive near-periodically with ±10% jitter, batch sizes are
/// log-uniform, and every `accum_steps`-th step is a gradient-sync step
/// (deterministic counter, so replay is independent of the rate).
#[derive(Clone, Debug)]
pub struct TrainingGenerator {
    rng: Rng,
    /// Mean step rate, optimizer steps/second. Burst events rescale this
    /// exactly like an inference tenant's request rate.
    pub rate_hz: f64,
    /// Micro-batches per gradient accumulation round.
    pub accum_steps: u32,
    /// Upper bound on tokens per micro-batch.
    pub max_batch_tokens: u64,
    step: u64,
}

impl TrainingGenerator {
    pub fn new(seed: u64, rate_hz: f64) -> TrainingGenerator {
        TrainingGenerator { rng: Rng::new(seed), rate_hz, accum_steps: 4, max_batch_tokens: 8192, step: 0 }
    }

    /// Builder: override the gradient-accumulation length (clamped to at
    /// least 1 so every stream eventually syncs).
    pub fn with_accum(mut self, accum_steps: u32) -> TrainingGenerator {
        self.accum_steps = accum_steps.max(1);
        self
    }

    /// Draw the stream's next step. The sync flag comes from the step
    /// counter alone; only pacing jitter and batch size consume RNG
    /// draws, so rescaling `rate_hz` mid-stream (bursts) never perturbs
    /// which steps sync.
    pub fn next_step(&mut self) -> TrainStep {
        self.step += 1;
        let jitter = self.rng.f64_range(0.9, 1.1);
        let batch = log_uniform_len(&mut self.rng, 8.0, self.max_batch_tokens);
        TrainStep {
            inter_arrival_ns: jitter / self.rate_hz * 1e9,
            batch_tokens: batch,
            grad_sync: self.step % self.accum_steps as u64 == 0,
        }
    }
}

/// Kernel mixes for the background/noisy tenants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mix {
    /// Compute-heavy (GEMM-dominated).
    Compute,
    /// Memory-bandwidth heavy (streaming).
    Bandwidth,
    /// Alloc/free churn.
    AllocChurn,
    /// Inference-like: alternating prefill/decode.
    Inference,
}

impl Mix {
    /// Next kernel in this mix (for mixes that launch kernels).
    pub fn kernel(&self, rng: &mut Rng) -> KernelDesc {
        match self {
            Mix::Compute => {
                let d = *rng.choose(&[2048u64, 3072, 4096]);
                KernelDesc::gemm(d, d, d, false)
            }
            Mix::Bandwidth => KernelDesc::streaming(rng.f64_range(0.5e9, 2e9)),
            Mix::AllocChurn => KernelDesc::null(),
            Mix::Inference => {
                if rng.chance(0.2) {
                    KernelDesc::attention(8, 1024, 64, true) // prefill
                } else {
                    KernelDesc::gemm(4096, 8, 4096, true) // decode
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_roughly_matches() {
        let mut g = RequestGenerator::new(1, 100.0);
        let trace = g.trace(4000);
        let mean_ns: f64 =
            trace.iter().map(|r| r.inter_arrival_ns).sum::<f64>() / trace.len() as f64;
        // 100 Hz → 10 ms mean inter-arrival.
        assert!((mean_ns / 1e6 - 10.0).abs() < 1.0, "mean={mean_ns}");
    }

    #[test]
    fn lengths_in_bounds() {
        let mut g = RequestGenerator::new(2, 10.0);
        for r in g.trace(500) {
            assert!(r.prompt_len >= 32 && r.prompt_len <= 2048);
            assert!(r.gen_len >= 8 && r.gen_len <= 256);
        }
    }

    #[test]
    fn mixes_generate_kernels() {
        let mut rng = Rng::new(3);
        assert!(Mix::Compute.kernel(&mut rng).flops > 1e9);
        assert!(Mix::Bandwidth.kernel(&mut rng).bytes > 1e8);
        let inf = Mix::Inference.kernel(&mut rng);
        assert!(inf.half_precision);
    }

    #[test]
    fn small_length_caps_stay_in_bounds() {
        // Regression test: caps below the log-uniform floors (2^5 prompt,
        // 2^3 gen) used to invert the exponent range and sample *outside*
        // [1, max]; the clamped bounds collapse to the cap instead.
        let mut g = RequestGenerator::new(5, 10.0).with_lengths(16, 4);
        for r in g.trace(300) {
            assert!(r.prompt_len >= 1 && r.prompt_len <= 16, "prompt={}", r.prompt_len);
            assert!(r.gen_len >= 1 && r.gen_len <= 4, "gen={}", r.gen_len);
        }
        // Degenerate 1-token caps are the constant 1.
        let mut g = RequestGenerator::new(6, 10.0).with_lengths(1, 1);
        for r in g.trace(50) {
            assert_eq!((r.prompt_len, r.gen_len), (1, 1));
        }
    }

    #[test]
    fn request_kernels_are_phase_shaped() {
        let mut g = RequestGenerator::new(8, 10.0).with_lengths(512, 64);
        let r = g.next_request();
        let prefill = r.prefill_kernel();
        let decode = r.decode_kernel();
        // Prefill compute scales with prompt²; decode is weight-bound and
        // linear in generated tokens.
        assert!(prefill.half_precision && decode.half_precision);
        assert!(
            (prefill.flops - 4.0 * (r.prompt_len * r.prompt_len * 64) as f64).abs() < 1.0
        );
        assert!((decode.bytes - 50e6 * r.gen_len as f64).abs() < 1.0);
        assert!(decode.intensity() < 5.0, "decode must be memory-bound");
    }

    #[test]
    fn batched_protos_realize_bit_identically() {
        // The dynsim engine pre-draws protos in blocks and realizes them
        // at consumption, possibly after a burst rescaled `rate_hz`.
        // Replay the same stream both ways — direct draws with the rate
        // changing mid-stream vs. protos drawn up front and realized at
        // the same per-request rates — and require bit-equality.
        let rates = [40.0, 40.0, 160.0, 160.0, 160.0, 40.0, 40.0, 40.0];
        let mut direct = RequestGenerator::new(99, rates[0]).with_lengths(512, 64);
        let mut batched = RequestGenerator::new(99, rates[0]).with_lengths(512, 64);
        let protos: Vec<ProtoRequest> = (0..rates.len()).map(|_| batched.next_proto()).collect();
        for (i, &rate) in rates.iter().enumerate() {
            direct.rate_hz = rate;
            batched.rate_hz = rate;
            let a = direct.next_request();
            let b = batched.realize(protos[i]);
            assert_eq!(
                a.inter_arrival_ns.to_bits(),
                b.inter_arrival_ns.to_bits(),
                "request {i} at {rate} Hz: {} vs {}",
                a.inter_arrival_ns,
                b.inter_arrival_ns
            );
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.gen_len, b.gen_len);
            assert_eq!(a.batchable, b.batchable);
        }
    }

    #[test]
    fn deterministic_traces() {
        let t1 = RequestGenerator::new(7, 50.0).trace(10);
        let t2 = RequestGenerator::new(7, 50.0).trace(10);
        for (a, b) in t1.iter().zip(&t2) {
            assert_eq!(a.prompt_len, b.prompt_len);
        }
    }

    #[test]
    fn training_steps_are_paced_not_poisson() {
        let mut g = TrainingGenerator::new(11, 20.0);
        // 20 steps/s → 50 ms mean pacing; jitter keeps every draw within
        // ±10% instead of an exponential's long tail.
        for _ in 0..500 {
            let s = g.next_step();
            let ms = s.inter_arrival_ns / 1e6;
            assert!((45.0..=55.0).contains(&ms), "pacing {ms} ms outside jitter band");
            assert!(s.batch_tokens >= 256 && s.batch_tokens <= 8192);
        }
    }

    #[test]
    fn grad_sync_follows_the_accum_counter_regardless_of_rate() {
        let mut g = TrainingGenerator::new(12, 10.0).with_accum(4);
        let mut syncs = Vec::new();
        for i in 1..=16u64 {
            if i == 7 {
                g.rate_hz = 80.0; // burst mid-stream
            }
            if g.next_step().grad_sync {
                syncs.push(i);
            }
        }
        assert_eq!(syncs, vec![4, 8, 12, 16]);
        // Degenerate accumulation clamps to 1: every step syncs.
        let mut g = TrainingGenerator::new(13, 10.0).with_accum(0);
        assert!(g.next_step().grad_sync);
    }

    #[test]
    fn training_kernels_are_phase_shaped() {
        let mut g = TrainingGenerator::new(14, 20.0);
        let s = g.next_step();
        let fwd = s.forward_kernel();
        let bwd = s.backward_kernel();
        let opt = s.optimizer_kernel();
        // Backward is exactly 2x forward compute; both are bf16
        // compute-bound at training batch sizes.
        assert!((bwd.flops - 2.0 * fwd.flops).abs() < 1.0);
        assert!(fwd.half_precision && bwd.half_precision);
        assert!(fwd.intensity() > 50.0, "forward must be compute-bound");
        // The optimizer streams fp32 state and is memory-bound.
        assert!(!opt.half_precision);
        assert!(opt.intensity() < 1.0, "optimizer must be memory-bound");
        // bf16 gradients: 2 bytes/param.
        assert_eq!(s.allreduce_bytes(), 50_000_000);
    }

    #[test]
    fn training_streams_are_deterministic() {
        let mut a = TrainingGenerator::new(21, 15.0);
        let mut b = TrainingGenerator::new(21, 15.0);
        for _ in 0..100 {
            let (x, y) = (a.next_step(), b.next_step());
            assert_eq!(x.inter_arrival_ns.to_bits(), y.inter_arrival_ns.to_bits());
            assert_eq!(x.batch_tokens, y.batch_tokens);
            assert_eq!(x.grad_sync, y.grad_sync);
        }
    }
}
