//! Workload generators: request arrival processes and kernel mixes used by
//! the examples and the end-to-end OH-010-style runs.

use crate::simgpu::kernel::KernelDesc;
use crate::util::Rng;

/// A generated inference request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Arrival time offset from the previous request, ns.
    pub inter_arrival_ns: f64,
    /// Prompt length (tokens).
    pub prompt_len: u64,
    /// Tokens to generate.
    pub gen_len: u64,
    /// Batch-able (shares a decode step with others).
    pub batchable: bool,
}

/// Poisson request generator with LLM-serving-shaped length distributions.
#[derive(Clone, Debug)]
pub struct RequestGenerator {
    rng: Rng,
    /// Mean arrival rate, requests/second.
    pub rate_hz: f64,
    pub max_prompt: u64,
    pub max_gen: u64,
}

impl RequestGenerator {
    pub fn new(seed: u64, rate_hz: f64) -> RequestGenerator {
        RequestGenerator { rng: Rng::new(seed), rate_hz, max_prompt: 2048, max_gen: 256 }
    }

    pub fn next_request(&mut self) -> Request {
        let inter = self.rng.exponential(self.rate_hz) * 1e9;
        // Prompt lengths are long-tailed; use a simple log-uniform.
        let prompt = (2f64).powf(self.rng.f64_range(5.0, (self.max_prompt as f64).log2()));
        let gen = (2f64).powf(self.rng.f64_range(3.0, (self.max_gen as f64).log2()));
        Request {
            inter_arrival_ns: inter,
            prompt_len: prompt as u64,
            gen_len: gen as u64,
            batchable: self.rng.chance(0.8),
        }
    }

    /// Generate a trace of `n` requests.
    pub fn trace(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

/// Kernel mixes for the background/noisy tenants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mix {
    /// Compute-heavy (GEMM-dominated).
    Compute,
    /// Memory-bandwidth heavy (streaming).
    Bandwidth,
    /// Alloc/free churn.
    AllocChurn,
    /// Inference-like: alternating prefill/decode.
    Inference,
}

impl Mix {
    /// Next kernel in this mix (for mixes that launch kernels).
    pub fn kernel(&self, rng: &mut Rng) -> KernelDesc {
        match self {
            Mix::Compute => {
                let d = *rng.choose(&[2048u64, 3072, 4096]);
                KernelDesc::gemm(d, d, d, false)
            }
            Mix::Bandwidth => KernelDesc::streaming(rng.f64_range(0.5e9, 2e9)),
            Mix::AllocChurn => KernelDesc::null(),
            Mix::Inference => {
                if rng.chance(0.2) {
                    KernelDesc::attention(8, 1024, 64, true) // prefill
                } else {
                    KernelDesc::gemm(4096, 8, 4096, true) // decode
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_roughly_matches() {
        let mut g = RequestGenerator::new(1, 100.0);
        let trace = g.trace(4000);
        let mean_ns: f64 =
            trace.iter().map(|r| r.inter_arrival_ns).sum::<f64>() / trace.len() as f64;
        // 100 Hz → 10 ms mean inter-arrival.
        assert!((mean_ns / 1e6 - 10.0).abs() < 1.0, "mean={mean_ns}");
    }

    #[test]
    fn lengths_in_bounds() {
        let mut g = RequestGenerator::new(2, 10.0);
        for r in g.trace(500) {
            assert!(r.prompt_len >= 32 && r.prompt_len <= 2048);
            assert!(r.gen_len >= 8 && r.gen_len <= 256);
        }
    }

    #[test]
    fn mixes_generate_kernels() {
        let mut rng = Rng::new(3);
        assert!(Mix::Compute.kernel(&mut rng).flops > 1e9);
        assert!(Mix::Bandwidth.kernel(&mut rng).bytes > 1e8);
        let inf = Mix::Inference.kernel(&mut rng);
        assert!(inf.half_precision);
    }

    #[test]
    fn deterministic_traces() {
        let t1 = RequestGenerator::new(7, 50.0).trace(10);
        let t2 = RequestGenerator::new(7, 50.0).trace(10);
        for (a, b) in t1.iter().zip(&t2) {
            assert_eq!(a.prompt_len, b.prompt_len);
        }
    }
}
