//! Workload generators: request arrival processes and kernel mixes.
//!
//! [`RequestGenerator`] produces Poisson arrivals with LLM-serving-shaped
//! (log-uniform) prompt/generation lengths. Its primary consumer is the
//! `dynsim` virtual-time dynamic-scenario engine
//! ([`crate::dynsim::engine`]), which drives one generator per simulated
//! tenant — rescaling `rate_hz` for burst phases — and turns each
//! [`Request`] into its prefill/decode kernel pair
//! ([`Request::prefill_kernel`] / [`Request::decode_kernel`]). The
//! examples and the end-to-end OH-010-style runs use the same generators
//! for open-loop load.

use crate::simgpu::kernel::KernelDesc;
use crate::util::Rng;

/// A generated inference request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Arrival time offset from the previous request, ns.
    pub inter_arrival_ns: f64,
    /// Prompt length (tokens).
    pub prompt_len: u64,
    /// Tokens to generate.
    pub gen_len: u64,
    /// Batch-able (shares a decode step with others).
    pub batchable: bool,
}

impl Request {
    /// The request's prefill phase: one fused attention pass over the
    /// prompt (bf16) — compute scales with `prompt_len²`.
    pub fn prefill_kernel(&self) -> KernelDesc {
        KernelDesc::attention(1, self.prompt_len.max(1), 64, true)
    }

    /// The request's decode phase as one fused kernel covering all
    /// generated tokens: the classic weight-streaming-bound regime (a
    /// ~25M-param layer group's bf16 weights re-read once per token), so
    /// service time scales linearly with `gen_len`.
    pub fn decode_kernel(&self) -> KernelDesc {
        let params = 25_000_000f64;
        let tokens = self.gen_len.max(1) as f64;
        KernelDesc {
            flops: 2.0 * params * tokens,
            bytes: params * 2.0 * tokens,
            half_precision: true,
            occupancy: 1.0,
        }
    }
}

/// A rate-independent request drawn ahead of time: everything
/// [`RequestGenerator::next_request`] samples except the arrival-rate
/// scaling. The dynsim engine draws these in batches per tenant and
/// realizes each against the rate current at consumption
/// ([`RequestGenerator::realize`]), which is bit-identical to a direct
/// `next_request` call at the same point — the unit-rate exponential
/// divides by the rate at realization, and `x / 1.0` is exact — while
/// amortizing generator-call overhead across the batch.
#[derive(Clone, Copy, Debug)]
pub struct ProtoRequest {
    /// Unit-rate exponential inter-arrival draw (seconds at 1 Hz).
    pub exp_unit: f64,
    pub prompt_len: u64,
    pub gen_len: u64,
    pub batchable: bool,
}

/// Poisson request generator with LLM-serving-shaped length distributions.
#[derive(Clone, Debug)]
pub struct RequestGenerator {
    rng: Rng,
    /// Mean arrival rate, requests/second.
    pub rate_hz: f64,
    pub max_prompt: u64,
    pub max_gen: u64,
}

/// Log-uniform length sample in `[2^lo_exp, max]`, clamping the exponent
/// range so it never inverts when `max < 2^lo_exp` (small caps collapse
/// to the constant `max` instead of sampling outside the bounds).
fn log_uniform_len(rng: &mut Rng, lo_exp: f64, max: u64) -> u64 {
    let hi = (max.max(1) as f64).log2();
    let lo = lo_exp.min(hi);
    ((2f64).powf(rng.f64_range(lo, hi)) as u64).clamp(1, max.max(1))
}

impl RequestGenerator {
    pub fn new(seed: u64, rate_hz: f64) -> RequestGenerator {
        RequestGenerator { rng: Rng::new(seed), rate_hz, max_prompt: 2048, max_gen: 256 }
    }

    /// Builder: override the prompt/generation length caps (the dynsim
    /// engine uses serving-scaled caps so scenario timelines stay cheap).
    pub fn with_lengths(mut self, max_prompt: u64, max_gen: u64) -> RequestGenerator {
        self.max_prompt = max_prompt;
        self.max_gen = max_gen;
        self
    }

    pub fn next_request(&mut self) -> Request {
        let proto = self.next_proto();
        self.realize(proto)
    }

    /// Draw the stream's next request with the arrival-rate scaling left
    /// out. Consumes exactly the draws `next_request` would (in the same
    /// order), so interleaving proto and direct draws keeps the stream
    /// aligned.
    pub fn next_proto(&mut self) -> ProtoRequest {
        let exp_unit = self.rng.exponential(1.0);
        // Prompt lengths are long-tailed; use a simple log-uniform.
        let prompt = log_uniform_len(&mut self.rng, 5.0, self.max_prompt);
        let gen = log_uniform_len(&mut self.rng, 3.0, self.max_gen);
        ProtoRequest {
            exp_unit,
            prompt_len: prompt,
            gen_len: gen,
            batchable: self.rng.chance(0.8),
        }
    }

    /// Realize a proto-request against the *current* `rate_hz`.
    /// Bit-identical to the request `next_request` would have produced
    /// from the same draws at this rate: `exponential(r)` divides the
    /// unit-rate draw by `r`, so `(exp_unit / r) * 1e9` reproduces
    /// `exponential(r) * 1e9` exactly.
    pub fn realize(&self, proto: ProtoRequest) -> Request {
        Request {
            inter_arrival_ns: proto.exp_unit / self.rate_hz * 1e9,
            prompt_len: proto.prompt_len,
            gen_len: proto.gen_len,
            batchable: proto.batchable,
        }
    }

    /// Generate a trace of `n` requests.
    pub fn trace(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

/// Kernel mixes for the background/noisy tenants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mix {
    /// Compute-heavy (GEMM-dominated).
    Compute,
    /// Memory-bandwidth heavy (streaming).
    Bandwidth,
    /// Alloc/free churn.
    AllocChurn,
    /// Inference-like: alternating prefill/decode.
    Inference,
}

impl Mix {
    /// Next kernel in this mix (for mixes that launch kernels).
    pub fn kernel(&self, rng: &mut Rng) -> KernelDesc {
        match self {
            Mix::Compute => {
                let d = *rng.choose(&[2048u64, 3072, 4096]);
                KernelDesc::gemm(d, d, d, false)
            }
            Mix::Bandwidth => KernelDesc::streaming(rng.f64_range(0.5e9, 2e9)),
            Mix::AllocChurn => KernelDesc::null(),
            Mix::Inference => {
                if rng.chance(0.2) {
                    KernelDesc::attention(8, 1024, 64, true) // prefill
                } else {
                    KernelDesc::gemm(4096, 8, 4096, true) // decode
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_roughly_matches() {
        let mut g = RequestGenerator::new(1, 100.0);
        let trace = g.trace(4000);
        let mean_ns: f64 =
            trace.iter().map(|r| r.inter_arrival_ns).sum::<f64>() / trace.len() as f64;
        // 100 Hz → 10 ms mean inter-arrival.
        assert!((mean_ns / 1e6 - 10.0).abs() < 1.0, "mean={mean_ns}");
    }

    #[test]
    fn lengths_in_bounds() {
        let mut g = RequestGenerator::new(2, 10.0);
        for r in g.trace(500) {
            assert!(r.prompt_len >= 32 && r.prompt_len <= 2048);
            assert!(r.gen_len >= 8 && r.gen_len <= 256);
        }
    }

    #[test]
    fn mixes_generate_kernels() {
        let mut rng = Rng::new(3);
        assert!(Mix::Compute.kernel(&mut rng).flops > 1e9);
        assert!(Mix::Bandwidth.kernel(&mut rng).bytes > 1e8);
        let inf = Mix::Inference.kernel(&mut rng);
        assert!(inf.half_precision);
    }

    #[test]
    fn small_length_caps_stay_in_bounds() {
        // Regression test: caps below the log-uniform floors (2^5 prompt,
        // 2^3 gen) used to invert the exponent range and sample *outside*
        // [1, max]; the clamped bounds collapse to the cap instead.
        let mut g = RequestGenerator::new(5, 10.0).with_lengths(16, 4);
        for r in g.trace(300) {
            assert!(r.prompt_len >= 1 && r.prompt_len <= 16, "prompt={}", r.prompt_len);
            assert!(r.gen_len >= 1 && r.gen_len <= 4, "gen={}", r.gen_len);
        }
        // Degenerate 1-token caps are the constant 1.
        let mut g = RequestGenerator::new(6, 10.0).with_lengths(1, 1);
        for r in g.trace(50) {
            assert_eq!((r.prompt_len, r.gen_len), (1, 1));
        }
    }

    #[test]
    fn request_kernels_are_phase_shaped() {
        let mut g = RequestGenerator::new(8, 10.0).with_lengths(512, 64);
        let r = g.next_request();
        let prefill = r.prefill_kernel();
        let decode = r.decode_kernel();
        // Prefill compute scales with prompt²; decode is weight-bound and
        // linear in generated tokens.
        assert!(prefill.half_precision && decode.half_precision);
        assert!(
            (prefill.flops - 4.0 * (r.prompt_len * r.prompt_len * 64) as f64).abs() < 1.0
        );
        assert!((decode.bytes - 50e6 * r.gen_len as f64).abs() < 1.0);
        assert!(decode.intensity() < 5.0, "decode must be memory-bound");
    }

    #[test]
    fn batched_protos_realize_bit_identically() {
        // The dynsim engine pre-draws protos in blocks and realizes them
        // at consumption, possibly after a burst rescaled `rate_hz`.
        // Replay the same stream both ways — direct draws with the rate
        // changing mid-stream vs. protos drawn up front and realized at
        // the same per-request rates — and require bit-equality.
        let rates = [40.0, 40.0, 160.0, 160.0, 160.0, 40.0, 40.0, 40.0];
        let mut direct = RequestGenerator::new(99, rates[0]).with_lengths(512, 64);
        let mut batched = RequestGenerator::new(99, rates[0]).with_lengths(512, 64);
        let protos: Vec<ProtoRequest> = (0..rates.len()).map(|_| batched.next_proto()).collect();
        for (i, &rate) in rates.iter().enumerate() {
            direct.rate_hz = rate;
            batched.rate_hz = rate;
            let a = direct.next_request();
            let b = batched.realize(protos[i]);
            assert_eq!(
                a.inter_arrival_ns.to_bits(),
                b.inter_arrival_ns.to_bits(),
                "request {i} at {rate} Hz: {} vs {}",
                a.inter_arrival_ns,
                b.inter_arrival_ns
            );
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.gen_len, b.gen_len);
            assert_eq!(a.batchable, b.batchable);
        }
    }

    #[test]
    fn deterministic_traces() {
        let t1 = RequestGenerator::new(7, 50.0).trace(10);
        let t2 = RequestGenerator::new(7, 50.0).trace(10);
        for (a, b) in t1.iter().zip(&t2) {
            assert_eq!(a.prompt_len, b.prompt_len);
        }
    }
}
