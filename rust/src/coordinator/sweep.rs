//! Scenario-matrix sweeps: the (systems × tenant counts × quota levels ×
//! GPU counts × link kinds × metrics) evaluation grid, executed as one
//! flat task list through the parallel sharded executor.
//!
//! The single-point suite answers "how good is system S at the default
//! operating point"; isolation and fragmentation behaviour only becomes
//! visible when swept across tenant counts and partition sizes (MIGPerf,
//! arXiv 2301.00407; fragmentation-aware scheduling, arXiv 2511.18906),
//! and multi-GPU communication behaviour only when the node topology is
//! an explicit axis (LLM-era sharing, arXiv 2508.08448). A [`SweepSpec`]
//! names the grid; [`run_sweep`] expands it:
//!
//! 1. Scenarios are the (tenants, quota) cross product, deduplicated, with
//!    the **baseline scenario** (1 tenant, 100 % quota) prepended if
//!    absent. Topologies are the (gpu_count, link) cross product — the
//!    full cell coordinate is `(system, tenants, quota_pct, gpu_count,
//!    link)`, and every cell reports its score delta against the baseline
//!    scenario **of its own (system, topology) block**, so NVLink and
//!    PCIe nodes are each compared against themselves.
//! 2. Every (system, topology, scenario, metric) cell becomes one executor
//!    task with a fully pre-derived [`RunConfig`]: quota maps onto
//!    `mem_limit` / `sm_limit` (percent of the whole device granted to
//!    each tenant), `gpu_count` / `link` select the simulated node the
//!    NCCL/P2P and PCIe backends build, and the per-task seed is
//!    `task_seed(topology_seed(scenario_seed(run_seed, tenants, quota),
//!    gpus, link), system, metric)` — a pure function of the cell
//!    coordinates, so a sweep is **bit-identical at any `--jobs` count**
//!    (proven by `rust/tests/sweep_determinism.rs`).
//! 3. Results re-assemble into per-cell [`ScoreCard`]s against the
//!    MIG-Ideal spec baseline, forming the [`SweepSurface`] that
//!    `report::sweep` renders as JSON / CSV / TXT.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::metrics::{registry, taxonomy, Category, MetricResult, RunConfig};
use crate::scoring::{Grade, ScoreCard};
use crate::simgpu::nvlink::LinkKind;
use crate::simgpu::GpuSpec;
use crate::util::rng::{scenario_seed, topology_seed};
use crate::virt::ALL_SYSTEMS;

use super::executor::{self, Backend, ExecutionStats, Observer, Task, TaskDone};

/// Tenant count of the baseline cell every delta is computed against.
pub const BASELINE_TENANTS: u32 = 1;
/// Quota percent of the baseline cell every delta is computed against.
pub const BASELINE_QUOTA_PCT: u32 = 100;
/// GPU count of the default node — the topology every pre-topology-axis
/// (PR-3-era) baseline row is re-run on, and the single value the default
/// grid evaluates.
pub const DEFAULT_GPU_COUNT: u32 = 4;
/// Link kind of the default node (the paper's A100 PCIe testbed).
pub const DEFAULT_LINK: LinkKind = LinkKind::Pcie;

/// A sweep specification: which systems to evaluate over which
/// (tenant count × quota percent) scenario grid and which
/// (gpu_count × link) node topologies, optionally restricted to a set of
/// metric categories.
///
/// # Examples
///
/// ```
/// use gvb::coordinator::sweep::SweepSpec;
/// use gvb::simgpu::nvlink::LinkKind;
///
/// let spec = SweepSpec {
///     systems: vec!["hami".into()],
///     tenants: vec![2, 4],
///     quotas: vec![50],
///     gpu_counts: vec![2, 4],
///     links: vec![LinkKind::NvLink, LinkKind::Pcie],
///     categories: None,
/// };
/// // The baseline scenario (1 tenant, 100 % quota) is injected first…
/// assert_eq!(spec.scenarios(), vec![(1, 100), (2, 50), (4, 50)]);
/// // …and the topology axes expand as a cross product.
/// assert_eq!(spec.topologies().len(), 4);
/// assert_eq!(spec.topologies()[0], (2, LinkKind::NvLink));
/// ```
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Backend keys (`native` / `hami` / `fcsp` / `mig` / `timeslice`).
    pub systems: Vec<String>,
    /// Tenant counts to sweep (e.g. `1,2,4,8`).
    pub tenants: Vec<u32>,
    /// Per-tenant quota levels in percent of the whole device (memory and
    /// SM alike); 100 % = unconstrained.
    pub quotas: Vec<u32>,
    /// GPU counts of the simulated node (`--gpus 2,4,8`); an empty list
    /// falls back to [`DEFAULT_GPU_COUNT`].
    pub gpu_counts: Vec<u32>,
    /// Interconnect kinds of the simulated node (`--link nvlink,pcie`);
    /// an empty list falls back to [`DEFAULT_LINK`].
    pub links: Vec<LinkKind>,
    /// Restrict to these metric categories (None = all 56 metrics).
    pub categories: Option<Vec<Category>>,
}

impl SweepSpec {
    /// The default grid: all Table-2 systems × tenants 1,2,4,8 × quotas
    /// 25,50,100 % on the default 4-GPU PCIe node, over the full taxonomy.
    pub fn default_grid() -> SweepSpec {
        SweepSpec {
            systems: ALL_SYSTEMS.iter().map(|s| s.to_string()).collect(),
            tenants: vec![1, 2, 4, 8],
            quotas: vec![25, 50, 100],
            gpu_counts: vec![DEFAULT_GPU_COUNT],
            links: vec![DEFAULT_LINK],
            categories: None,
        }
    }

    /// The deduplicated (tenants, quota) scenario list, baseline cell
    /// first if it isn't already part of the grid.
    pub fn scenarios(&self) -> Vec<(u32, u32)> {
        let mut out: Vec<(u32, u32)> = Vec::new();
        if !(self.tenants.contains(&BASELINE_TENANTS) && self.quotas.contains(&BASELINE_QUOTA_PCT))
        {
            out.push((BASELINE_TENANTS, BASELINE_QUOTA_PCT));
        }
        for &t in &self.tenants {
            for &q in &self.quotas {
                out.push((t, q));
            }
        }
        let mut seen = HashSet::new();
        out.retain(|s| seen.insert(*s));
        out
    }

    /// The deduplicated (gpu_count, link) topology list, in grid order
    /// (gpu counts outer, link kinds inner). Empty axes fall back to the
    /// default 4-GPU PCIe node so a spec without topology lists behaves
    /// exactly like the pre-topology-axis sweep.
    pub fn topologies(&self) -> Vec<(u32, LinkKind)> {
        let mut out: Vec<(u32, LinkKind)> = Vec::new();
        let gpus: &[u32] =
            if self.gpu_counts.is_empty() { &[DEFAULT_GPU_COUNT] } else { &self.gpu_counts };
        let links: &[LinkKind] = if self.links.is_empty() { &[DEFAULT_LINK] } else { &self.links };
        for &g in gpus {
            for &l in links {
                out.push((g, l));
            }
        }
        let mut seen = HashSet::new();
        out.retain(|t| seen.insert(*t));
        out
    }

    /// Metric ids this spec evaluates, in global Table-8 order.
    pub fn metric_ids(&self) -> Vec<&'static str> {
        match &self.categories {
            Some(cats) => registry::ids_for_categories(cats),
            None => registry::all_ids(),
        }
    }
}

/// The per-cell config: `base` with the cell's system, tenant count,
/// quota and node topology applied. Quota is the percent of the full
/// device granted to each tenant, for memory quota and SM limit alike —
/// so (1 tenant, 100 %) is the unconstrained baseline and (4 tenants,
/// 25 %) reproduces the paper's default equal-share-of-four operating
/// point. `gpu_count` / `link` select the simulated node the NCCL/P2P
/// and PCIe metric backends build. The seed becomes the composed
/// scenario+topology seed; the executor then derives per-metric task
/// seeds from it, so the full chain is
/// `task_seed(topology_seed(scenario_seed(run_seed, tenants, quota),
/// gpus, link), system, metric)`.
pub fn cell_cfg(
    base: &RunConfig,
    system: &str,
    tenants: u32,
    quota_pct: u32,
    gpu_count: u32,
    link: LinkKind,
) -> RunConfig {
    let dev_mem = GpuSpec::a100_40gb().hbm_bytes;
    let mut cfg = base.clone();
    cfg.system = system.to_string();
    cfg.tenants = tenants;
    cfg.mem_limit = dev_mem.saturating_mul(quota_pct as u64) / 100;
    cfg.sm_limit = quota_pct as f64 / 100.0;
    cfg.gpu_count = gpu_count;
    cfg.link = link;
    cfg.seed = topology_seed(scenario_seed(base.seed, tenants, quota_pct), gpu_count, link.key());
    cfg
}

/// The PR-3-era per-cell config: identical quota→mem/SM mapping and the
/// same default node the pre-topology-axis sweep hardcoded, but the seed
/// stops at the scenario layer — `task_seed(scenario_seed(seed, tenants,
/// quota), system, metric)` — exactly the derivation that produced
/// 4-tuple (no `gpu_count`/`link` columns) baselines. The regress engine
/// re-runs topology-less rows through this so genuinely old baselines
/// compare bit-identically against an unchanged tree.
pub fn legacy_cell_cfg(
    base: &RunConfig,
    system: &str,
    tenants: u32,
    quota_pct: u32,
) -> RunConfig {
    let mut cfg = cell_cfg(base, system, tenants, quota_pct, DEFAULT_GPU_COUNT, DEFAULT_LINK);
    cfg.seed = scenario_seed(base.seed, tenants, quota_pct);
    cfg
}

/// Whether a (system, tenants) combination can run at all. MIG-style
/// hardware partitioning exposes [`crate::virt::mig::COMPUTE_SLICES`]
/// compute slices on an A100, so such systems cannot host more concurrent
/// tenants than slices; the sweep records those cells as infeasible
/// instead of driving the backend into a registration failure. The
/// topology axes do not restrict feasibility: tenancy is per GPU.
pub fn cell_feasible(system: &str, tenants: u32) -> bool {
    match crate::virt::by_name(system) {
        Some(layer) => {
            !layer.hardware_isolated() || tenants <= crate::virt::mig::COMPUTE_SLICES
        }
        None => false,
    }
}

/// One scored (system, tenants, quota, gpu_count, link) cell of the
/// sweep surface.
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub system: String,
    pub tenants: u32,
    pub quota_pct: u32,
    /// GPUs in the cell's simulated node.
    pub gpu_count: u32,
    /// Interconnect of the cell's simulated node.
    pub link: LinkKind,
    /// Weighted overall score of this cell against the MIG-Ideal spec
    /// baseline (same scoring as the single-point suite). NaN when the
    /// cell is infeasible.
    pub overall: f64,
    /// Signed percent change of `overall` vs this system's baseline cell
    /// (1 tenant, 100 % quota) **on the same topology**; negative =
    /// degraded under the scenario.
    pub delta_vs_baseline_pct: f64,
    /// Category → mean score, in `Category::ALL` order (only categories
    /// the spec selected). Empty when the cell is infeasible.
    pub per_category: Vec<(Category, f64)>,
    pub grade: Grade,
    /// True for the (1 tenant, 100 % quota) reference scenario of its
    /// (system, topology) block.
    pub is_baseline: bool,
    /// False when the system cannot host the scenario at all (e.g. more
    /// tenants than MIG compute slices); such cells ran no metrics.
    pub feasible: bool,
    /// Raw per-metric results of this cell, in [`SweepSurface::metric_ids`]
    /// order (empty when infeasible). The long-format CSV surface — the
    /// per-cell baseline `gvbench regress` gates on — and the JSON
    /// reporter read these.
    pub results: Vec<MetricResult>,
}

/// A completed sweep: all scored cells plus the run's execution timings.
pub struct SweepSurface {
    /// The run seed the scenario/topology/task seeds were derived from.
    pub seed: u64,
    /// Metric ids evaluated in every cell, in Table-8 order.
    pub metric_ids: Vec<&'static str>,
    /// Cells in deterministic order: spec's system order, then topology
    /// order (gpu counts outer, links inner), then scenario order
    /// (baseline first when it was injected).
    pub cells: Vec<SweepCell>,
    /// Wall-clock + per-task timings of the whole flattened matrix.
    pub stats: ExecutionStats,
}

impl SweepSurface {
    /// The worst-degrading non-baseline feasible cell (most negative
    /// delta) per `key` group, in first-appearance order.
    fn worst_by_key<K: std::hash::Hash + Eq + Clone>(
        &self,
        key: impl Fn(&SweepCell) -> K,
    ) -> Vec<&SweepCell> {
        let mut order: Vec<K> = Vec::new();
        let mut worst: HashMap<K, &SweepCell> = HashMap::new();
        for c in &self.cells {
            if c.is_baseline || !c.feasible {
                continue;
            }
            let k = key(c);
            match worst.get(&k).map(|prev| prev.delta_vs_baseline_pct) {
                None => {
                    order.push(k.clone());
                    worst.insert(k, c);
                }
                Some(prev_delta) => {
                    if c.delta_vs_baseline_pct < prev_delta {
                        worst.insert(k, c);
                    }
                }
            }
        }
        order.iter().filter_map(|k| worst.get(k).copied()).collect()
    }

    /// The worst-degrading non-baseline cell (most negative delta) per
    /// system, in the surface's system order.
    pub fn worst_cells(&self) -> Vec<&SweepCell> {
        self.worst_by_key(|c| c.system.clone())
    }

    /// The worst-degrading non-baseline cell per (system, link kind), in
    /// first-appearance order — the per-link summary the TXT and JSON
    /// reporters surface so NVLink and PCIe nodes are each judged against
    /// their own baselines.
    pub fn worst_cells_per_link(&self) -> Vec<&SweepCell> {
        self.worst_by_key(|c| (c.system.clone(), c.link))
    }
}

/// Expand `spec` into a flat task list, execute it through the sharded
/// executor on `jobs` workers (0 = available parallelism), and score each
/// cell. `base` supplies iterations/warmup/seed; system, tenants, quota,
/// topology and per-task seeds are derived per cell.
pub fn run_sweep(base: &RunConfig, spec: &SweepSpec, jobs: usize) -> SweepSurface {
    run_sweep_on(&Backend::Scoped(jobs), base, spec, None)
}

/// [`run_sweep`] generalized over the pool shape: the same task list and
/// seed derivation, executed on `exec` (scoped threads or a persistent
/// serve-daemon pool), with an optional per-task completion observer.
/// Bit-identical to [`run_sweep`] at any worker count.
pub fn run_sweep_on(
    exec: &Backend<'_>,
    base: &RunConfig,
    spec: &SweepSpec,
    observer: Option<Observer>,
) -> SweepSurface {
    let ids = spec.metric_ids();
    let scenarios = spec.scenarios();
    let topologies = spec.topologies();

    // One flat (task, prepared config) list over the whole matrix, in
    // deterministic cell order.
    let mut pairs: Vec<(Task, RunConfig)> = Vec::with_capacity(
        spec.systems.len() * topologies.len() * scenarios.len() * ids.len(),
    );
    for system in &spec.systems {
        for &(gpus, link) in &topologies {
            for &(tenants, quota) in &scenarios {
                if !cell_feasible(system, tenants) {
                    continue; // recorded as an infeasible cell below
                }
                let cfg = cell_cfg(base, system, tenants, quota, gpus, link);
                for &id in &ids {
                    pairs.push((
                        Task { system: system.clone(), metric_id: id },
                        executor::derive_cfg(&cfg, system, id),
                    ));
                }
            }
        }
    }
    // Index-aligned execution: every id comes from the registry, so every
    // slot must be filled — a `None` (a taxonomy/registry divergence)
    // panics loudly below instead of silently shifting later cells'
    // results onto the wrong coordinates.
    let tasks: Arc<Vec<Task>> = Arc::new(pairs.iter().map(|(t, _)| t.clone()).collect());
    let total = tasks.len();
    let pairs = Arc::new(pairs);
    let run = {
        let pairs = Arc::clone(&pairs);
        move |i: usize, task: &Task| {
            let result = registry::run_metric(task.metric_id, &pairs[i].1);
            if let (Some(obs), Some(r)) = (observer.as_ref(), result.as_ref()) {
                obs(TaskDone {
                    index: i,
                    total,
                    system: task.system.clone(),
                    label: task.metric_id.to_string(),
                    value: r.value,
                });
            }
            result
        }
    };
    let (slots, stats) = executor::execute_indexed_on(exec, tasks, run);

    // Spec baseline (MIG-Ideal expected values), shared by every cell.
    let spec_baseline: Vec<MetricResult> = ids
        .iter()
        .map(|&id| MetricResult::from_value(id, "mig-ideal-spec", taxonomy::mig_baseline(id)))
        .collect();

    // Re-group the flat results into cells (all ids are registry-known, so
    // the executor returns exactly one result per task, in input order).
    let per_cell = ids.len();
    let mut cells: Vec<SweepCell> =
        Vec::with_capacity(spec.systems.len() * topologies.len() * scenarios.len());
    let mut offset = 0;
    for system in &spec.systems {
        for &(gpus, link) in &topologies {
            let first_cell_of_block = cells.len();
            for &(tenants, quota) in &scenarios {
                let is_baseline = tenants == BASELINE_TENANTS && quota == BASELINE_QUOTA_PCT;
                if !cell_feasible(system, tenants) {
                    cells.push(SweepCell {
                        system: system.clone(),
                        tenants,
                        quota_pct: quota,
                        gpu_count: gpus,
                        link,
                        overall: f64::NAN,
                        delta_vs_baseline_pct: 0.0,
                        per_category: Vec::new(),
                        grade: Grade::F,
                        is_baseline,
                        feasible: false,
                        results: Vec::new(),
                    });
                    continue;
                }
                let cell_results: Vec<MetricResult> = slots[offset..offset + per_cell]
                    .iter()
                    .zip(&ids)
                    .map(|(slot, id)| {
                        slot.as_ref()
                            .unwrap_or_else(|| {
                                panic!(
                                    "sweep cell {system}/{tenants}t/{quota}%/{gpus}g/{}: \
                                     metric `{id}` is in the taxonomy but not the runnable \
                                     registry",
                                    link.key()
                                )
                            })
                            .clone()
                    })
                    .collect();
                offset += per_cell;
                let card = ScoreCard::build(system, &cell_results, &spec_baseline);
                let per_category: Vec<(Category, f64)> = Category::ALL
                    .iter()
                    .filter_map(|c| card.per_category.get(c).map(|s| (*c, *s)))
                    .collect();
                cells.push(SweepCell {
                    system: system.clone(),
                    tenants,
                    quota_pct: quota,
                    gpu_count: gpus,
                    link,
                    overall: card.overall,
                    delta_vs_baseline_pct: 0.0,
                    per_category,
                    grade: card.grade(),
                    is_baseline,
                    feasible: true,
                    results: cell_results,
                });
            }
            // Deltas vs this (system, topology) block's baseline cell
            // (always present and feasible — it has 1 tenant — whether
            // in-grid or injected).
            let base_overall = cells[first_cell_of_block..]
                .iter()
                .find(|c| c.is_baseline)
                .map(|c| c.overall)
                .unwrap_or(f64::NAN);
            for c in &mut cells[first_cell_of_block..] {
                c.delta_vs_baseline_pct = if base_overall.abs() < 1e-12
                    || !base_overall.is_finite()
                    || !c.overall.is_finite()
                {
                    0.0
                } else {
                    (c.overall - base_overall) / base_overall * 100.0
                };
            }
        }
    }

    SweepSurface { seed: base.seed, metric_ids: ids, cells, stats }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> SweepSpec {
        SweepSpec {
            systems: vec!["native".into(), "hami".into()],
            tenants: vec![2, 4],
            quotas: vec![50],
            gpu_counts: vec![DEFAULT_GPU_COUNT],
            links: vec![DEFAULT_LINK],
            categories: Some(vec![Category::Pcie]),
        }
    }

    #[test]
    fn scenarios_inject_baseline_and_dedupe() {
        let s = small_spec();
        assert_eq!(s.scenarios(), vec![(1, 100), (2, 50), (4, 50)]);
        // Grid already containing the baseline cell: not injected twice.
        let full = SweepSpec {
            tenants: vec![1, 2],
            quotas: vec![100, 100],
            ..small_spec()
        };
        assert_eq!(full.scenarios(), vec![(1, 100), (2, 100)]);
    }

    #[test]
    fn topologies_cross_product_dedupes_and_defaults() {
        let s = SweepSpec {
            gpu_counts: vec![2, 4, 4],
            links: vec![LinkKind::NvLink, LinkKind::Pcie],
            ..small_spec()
        };
        assert_eq!(
            s.topologies(),
            vec![
                (2, LinkKind::NvLink),
                (2, LinkKind::Pcie),
                (4, LinkKind::NvLink),
                (4, LinkKind::Pcie),
            ]
        );
        // Empty axes fall back to the default 4-GPU PCIe node.
        let bare = SweepSpec { gpu_counts: vec![], links: vec![], ..small_spec() };
        assert_eq!(bare.topologies(), vec![(DEFAULT_GPU_COUNT, DEFAULT_LINK)]);
    }

    #[test]
    fn cell_cfg_maps_quota_topology_and_seed() {
        let base = RunConfig::quick("native");
        let cfg = cell_cfg(&base, "hami", 4, 25, 8, LinkKind::NvLink);
        assert_eq!(cfg.system, "hami");
        assert_eq!(cfg.tenants, 4);
        assert_eq!(cfg.mem_limit, 10 << 30); // 25 % of an A100-40GB
        assert!((cfg.sm_limit - 0.25).abs() < 1e-12);
        assert_eq!(cfg.gpu_count, 8);
        assert_eq!(cfg.link, LinkKind::NvLink);
        assert_eq!(
            cfg.seed,
            topology_seed(scenario_seed(base.seed, 4, 25), 8, "nvlink")
        );
        assert_eq!(cfg.iterations, base.iterations);
        // The unconstrained baseline cell grants the whole device.
        let b = cell_cfg(&base, "hami", 1, 100, DEFAULT_GPU_COUNT, DEFAULT_LINK);
        assert_eq!(b.mem_limit, 40u64 << 30);
        assert!((b.sm_limit - 1.0).abs() < 1e-12);
        // Same scenario on different topologies: different seeds.
        let nv = cell_cfg(&base, "hami", 4, 25, 4, LinkKind::NvLink);
        let pc = cell_cfg(&base, "hami", 4, 25, 4, LinkKind::Pcie);
        assert_ne!(nv.seed, pc.seed);
    }

    #[test]
    fn legacy_cell_cfg_matches_pr3_derivation() {
        let base = RunConfig::quick("native");
        let legacy = legacy_cell_cfg(&base, "hami", 4, 25);
        let modern = cell_cfg(&base, "hami", 4, 25, DEFAULT_GPU_COUNT, DEFAULT_LINK);
        // Same quota mapping and the same default node…
        assert_eq!(legacy.mem_limit, modern.mem_limit);
        assert!((legacy.sm_limit - modern.sm_limit).abs() < 1e-12);
        assert_eq!(legacy.gpu_count, DEFAULT_GPU_COUNT);
        assert_eq!(legacy.link, DEFAULT_LINK);
        // …but the seed stops at the scenario layer, exactly as the
        // pre-topology-axis sweep derived it.
        assert_eq!(legacy.seed, scenario_seed(base.seed, 4, 25));
        assert_ne!(legacy.seed, modern.seed);
    }

    #[test]
    fn sweep_shapes_and_baseline_deltas() {
        let base = RunConfig::quick("native");
        let surface = run_sweep(&base, &small_spec(), 2);
        // 2 systems × 1 topology × 3 scenarios (baseline injected) ×
        // 4 PCIe metrics.
        assert_eq!(surface.metric_ids.len(), 4);
        assert_eq!(surface.cells.len(), 6);
        assert_eq!(surface.stats.tasks.len(), 24);
        for c in &surface.cells {
            assert!(c.feasible);
            assert!(c.overall.is_finite(), "{}/{}t/{}%", c.system, c.tenants, c.quota_pct);
            assert!(!c.per_category.is_empty());
            assert_eq!(c.gpu_count, DEFAULT_GPU_COUNT);
            assert_eq!(c.link, DEFAULT_LINK);
            // Raw per-metric results ride along in metric_ids order.
            assert_eq!(c.results.len(), surface.metric_ids.len());
            for (r, id) in c.results.iter().zip(&surface.metric_ids) {
                assert_eq!(r.id, *id);
                assert_eq!(r.system, c.system);
            }
        }
        // First cell per (system, topology) block is the injected
        // baseline with delta 0.
        for sys_block in surface.cells.chunks(3) {
            assert!(sys_block[0].is_baseline);
            assert_eq!(sys_block[0].tenants, 1);
            assert_eq!(sys_block[0].quota_pct, 100);
            assert_eq!(sys_block[0].delta_vs_baseline_pct, 0.0);
        }
    }

    #[test]
    fn topology_axes_expand_cells_with_per_block_baselines() {
        let base = RunConfig::quick("native");
        let spec = SweepSpec {
            systems: vec!["native".into()],
            tenants: vec![2],
            quotas: vec![50],
            gpu_counts: vec![4, 8],
            links: vec![LinkKind::NvLink, LinkKind::Pcie],
            categories: Some(vec![Category::Nccl]),
        };
        let surface = run_sweep(&base, &spec, 2);
        // 1 system × 4 topologies × 2 scenarios ((1,100) injected) ×
        // 4 NCCL metrics.
        assert_eq!(surface.cells.len(), 8);
        assert_eq!(surface.stats.tasks.len(), 32);
        // Every topology block leads with its own baseline cell.
        for block in surface.cells.chunks(2) {
            assert!(block[0].is_baseline);
            assert_eq!(block[0].delta_vs_baseline_pct, 0.0);
            assert_eq!(block[0].gpu_count, block[1].gpu_count);
            assert_eq!(block[0].link, block[1].link);
        }
        // NCCL-003 (P2P GB/s) is far faster on the NVLink cells than the
        // PCIe cells of the same scenario: the topology actually reaches
        // the metric backends.
        let p2p = |link: LinkKind, gpus: u32| -> f64 {
            let c = surface
                .cells
                .iter()
                .find(|c| c.link == link && c.gpu_count == gpus && c.is_baseline)
                .unwrap();
            let idx =
                surface.metric_ids.iter().position(|id| *id == "NCCL-003").unwrap();
            c.results[idx].value
        };
        assert!(p2p(LinkKind::NvLink, 4) > p2p(LinkKind::Pcie, 4) * 5.0);
    }

    #[test]
    fn worst_cells_one_per_system() {
        let base = RunConfig::quick("native");
        let surface = run_sweep(&base, &small_spec(), 0);
        let worst = surface.worst_cells();
        assert_eq!(worst.len(), 2);
        assert_eq!(worst[0].system, "native");
        assert_eq!(worst[1].system, "hami");
        for w in worst {
            assert!(!w.is_baseline);
        }
    }

    #[test]
    fn worst_cells_per_link_split_by_link_kind() {
        let base = RunConfig::quick("native");
        let spec = SweepSpec {
            systems: vec!["hami".into()],
            tenants: vec![4],
            quotas: vec![25],
            gpu_counts: vec![4],
            links: vec![LinkKind::NvLink, LinkKind::Pcie],
            categories: Some(vec![Category::Pcie]),
        };
        let surface = run_sweep(&base, &spec, 2);
        let worst = surface.worst_cells_per_link();
        assert_eq!(worst.len(), 2);
        assert_eq!(worst[0].link, LinkKind::NvLink);
        assert_eq!(worst[1].link, LinkKind::Pcie);
        for w in &worst {
            assert_eq!(w.system, "hami");
            assert!(!w.is_baseline);
        }
        // The plain per-system summary still collapses to one cell.
        assert_eq!(surface.worst_cells().len(), 1);
    }

    #[test]
    fn pool_backend_matches_scoped_sweep_bitwise() {
        let base = RunConfig::quick("native");
        let scoped = run_sweep(&base, &small_spec(), 2);
        let pool = executor::WorkerPool::new(3);
        let seen = Arc::new(std::sync::Mutex::new(0usize));
        let observer: Observer = {
            let seen = Arc::clone(&seen);
            Arc::new(move |_done| *seen.lock().unwrap() += 1)
        };
        let pooled = run_sweep_on(&Backend::Pool(&pool), &base, &small_spec(), Some(observer));
        assert_eq!(scoped.cells.len(), pooled.cells.len());
        for (a, b) in scoped.cells.iter().zip(&pooled.cells) {
            assert_eq!((a.system.as_str(), a.tenants, a.quota_pct), (b.system.as_str(), b.tenants, b.quota_pct));
            assert_eq!(a.overall.to_bits(), b.overall.to_bits(), "{}", a.system);
            for (x, y) in a.results.iter().zip(&b.results) {
                assert_eq!(x.value.to_bits(), y.value.to_bits(), "{}", x.id);
            }
        }
        // The observer saw every executed task exactly once.
        assert_eq!(*seen.lock().unwrap(), pooled.stats.tasks.len());
    }

    #[test]
    fn default_grid_is_full_matrix() {
        let g = SweepSpec::default_grid();
        assert_eq!(g.systems.len(), 4);
        assert_eq!(g.scenarios().len(), 12); // 4×3, baseline in-grid
        assert_eq!(g.topologies(), vec![(DEFAULT_GPU_COUNT, DEFAULT_LINK)]);
        assert_eq!(g.metric_ids().len(), 56);
    }

    #[test]
    fn mig_over_slice_count_is_infeasible_not_a_panic() {
        // MIG exposes 7 compute slices; an 8-tenant cell cannot register
        // and must surface as `feasible: false` instead of driving the
        // backend into a context-creation failure.
        assert!(cell_feasible("mig", 7));
        assert!(!cell_feasible("mig", 8));
        assert!(cell_feasible("hami", 64));
        assert!(!cell_feasible("nope", 1));
        let spec = SweepSpec {
            systems: vec!["mig".into()],
            tenants: vec![8],
            quotas: vec![50],
            gpu_counts: vec![DEFAULT_GPU_COUNT],
            links: vec![DEFAULT_LINK],
            categories: Some(vec![Category::Pcie]),
        };
        let surface = run_sweep(&RunConfig::quick("native"), &spec, 2);
        // Injected (1,100) baseline + the infeasible (8,50) cell.
        assert_eq!(surface.cells.len(), 2);
        assert!(surface.cells[0].is_baseline && surface.cells[0].feasible);
        assert!(surface.cells[0].overall.is_finite());
        let infeasible = &surface.cells[1];
        assert!(!infeasible.feasible);
        assert!(infeasible.overall.is_nan());
        assert_eq!(infeasible.delta_vs_baseline_pct, 0.0);
        assert!(infeasible.per_category.is_empty());
        assert!(infeasible.results.is_empty());
        // Only the baseline cell's metrics actually ran.
        assert_eq!(surface.stats.tasks.len(), 4);
        // And it never shows up as a worst-degrading cell.
        assert!(surface.worst_cells().is_empty());
        assert!(surface.worst_cells_per_link().is_empty());
    }
}
