//! Multi-tenant orchestration: the parallel sharded suite executor
//! ([`executor`]), the suite runner ([`runner`]), the scenario-matrix
//! sweep subsystem ([`sweep`]), workload generators ([`workload`]) and a
//! thread-backed tenant harness ([`tenant`]) used by the examples to
//! drive real concurrent load against the PJRT runtime.

pub mod executor;
pub mod runner;
pub mod sweep;
pub mod tenant;
pub mod workload;

pub use executor::{ExecutionStats, Task, TaskTiming};
pub use runner::{SuiteResult, SuiteRunner};
pub use sweep::{SweepCell, SweepSpec, SweepSurface};
