//! Dynamics-grid scaling: wall-clock of a (systems × scenarios) timeline
//! grid at 1 → N executor workers, plus a bit-identity spot check between
//! the serial and widest runs.
//!
//! Timelines are coarser-grained tasks than single metrics (one task =
//! one whole scenario replay), so this also exercises the executor's
//! load balance on small task counts: with 16 timelines and N ≤ 16
//! workers the speedup floor is the longest single timeline.

use std::time::Instant;

use gvb::benchkit::print_table;
use gvb::dynsim::{run_dynamics, DynSpec, PRESETS};
use gvb::metrics::RunConfig;
use gvb::report::dynamics::render_summary_csv;
use gvb::virt::ALL_SYSTEMS;

fn main() {
    let base = RunConfig::quick("native");
    let spec = DynSpec {
        systems: ALL_SYSTEMS.iter().map(|s| s.to_string()).collect(),
        scenarios: PRESETS.to_vec(),
        duration_ms: 600,
        window_ms: 100,
    };
    println!(
        "Dynamics grid: {} systems x {} scenarios = {} timelines ({} ms horizon, {} ms windows)",
        spec.systems.len(),
        spec.scenarios.len(),
        spec.systems.len() * spec.scenarios.len(),
        spec.duration_ms,
        spec.window_ms
    );

    let hw = gvb::coordinator::executor::resolve_jobs(0);
    let mut job_counts = vec![1usize, 2, 4];
    if hw > 4 {
        job_counts.push(hw);
    }
    job_counts.dedup();

    let mut rows = Vec::new();
    let mut serial_s = 0.0;
    let mut serial_summary = String::new();
    for &jobs in &job_counts {
        let t0 = Instant::now();
        let surface = run_dynamics(&base, &spec, jobs);
        let dt = t0.elapsed().as_secs_f64();
        let summary = render_summary_csv(&surface);
        if jobs == 1 {
            serial_s = dt;
            serial_summary = summary;
        } else {
            assert_eq!(summary, serial_summary, "determinism violated at jobs={jobs}");
        }
        let requests: usize = surface.runs.iter().map(|r| r.completed).sum();
        rows.push(vec![
            jobs.to_string(),
            format!("{dt:.2}"),
            format!("{:.2}x", serial_s / dt),
            format!("{:.2}x", surface.stats.speedup_estimate()),
            format!("{:.0} ms", surface.stats.max_task_ns() as f64 / 1e6),
            requests.to_string(),
        ]);
    }
    print_table(
        "Dynamics scaling — 4 systems x 4 scenarios",
        &["jobs", "wall s", "speedup vs 1", "busy/wall", "longest timeline", "requests"],
        &rows,
    );
    println!("\n(host parallelism: {hw}; summary CSV verified byte-identical across job counts)");
}
