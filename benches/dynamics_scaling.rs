//! Dynamics-grid scaling: wall-clock of a (systems × scenarios) timeline
//! grid at 1 → N executor workers, plus a bit-identity spot check between
//! the serial and widest runs.
//!
//! Timelines are coarser-grained tasks than single metrics (one task =
//! one whole scenario replay), so this also exercises the executor's
//! load balance on small task counts: with 16 timelines and N ≤ 16
//! workers the speedup floor is the longest single timeline.
//!
//! The second section is the **large-horizon stress** the event-queue
//! rewrite targets: a single uniform-load timeline with 10³ tenants and
//! ≥10⁶ occurrences. The pre-rewrite min-scan loop was
//! O(occurrences × tenants) here (~10⁹ scans); the event core is
//! O(occurrences × log tenants) and must finish in single-digit seconds.

use std::time::Instant;

use gvb::benchkit::print_table;
use gvb::dynsim::{engine, run_dynamics, DynSpec, ScenarioSpec, PRESETS};
use gvb::metrics::RunConfig;
use gvb::report::dynamics::render_summary_csv;
use gvb::util::rng::{dynamics_seed, task_seed};
use gvb::virt::ALL_SYSTEMS;

fn main() {
    let base = RunConfig::quick("native");
    let spec = DynSpec {
        systems: ALL_SYSTEMS.iter().map(|s| s.to_string()).collect(),
        scenarios: PRESETS.to_vec(),
        duration_ms: 600,
        window_ms: 100,
        trace: None,
    };
    println!(
        "Dynamics grid: {} systems x {} scenarios = {} timelines ({} ms horizon, {} ms windows)",
        spec.systems.len(),
        spec.scenarios.len(),
        spec.systems.len() * spec.scenarios.len(),
        spec.duration_ms,
        spec.window_ms
    );

    let hw = gvb::coordinator::executor::resolve_jobs(0);
    let mut job_counts = vec![1usize, 2, 4];
    if hw > 4 {
        job_counts.push(hw);
    }
    job_counts.dedup();

    let mut rows = Vec::new();
    let mut serial_s = 0.0;
    let mut serial_summary = String::new();
    for &jobs in &job_counts {
        let t0 = Instant::now();
        let surface = run_dynamics(&base, &spec, jobs);
        let dt = t0.elapsed().as_secs_f64();
        let summary = render_summary_csv(&surface);
        if jobs == 1 {
            serial_s = dt;
            serial_summary = summary;
        } else {
            assert_eq!(summary, serial_summary, "determinism violated at jobs={jobs}");
        }
        let requests: usize = surface.runs.iter().map(|r| r.completed).sum();
        rows.push(vec![
            jobs.to_string(),
            format!("{dt:.2}"),
            format!("{:.2}x", serial_s / dt),
            format!("{:.2}x", surface.stats.speedup_estimate()),
            format!("{:.0} ms", surface.stats.max_task_ns() as f64 / 1e6),
            requests.to_string(),
        ]);
    }
    print_table(
        "Dynamics scaling — 4 systems x 4 scenarios",
        &["jobs", "wall s", "speedup vs 1", "busy/wall", "longest timeline", "requests"],
        &rows,
    );
    println!("\n(host parallelism: {hw}; summary CSV verified byte-identical across job counts)");

    // ---- large-horizon stress: 10³ tenants, ≥10⁶ occurrences ----------
    // 1000 tenants × 10 Hz × 100 s ≈ 10⁶ request arrivals, plus 1000
    // arrival events and 100 window boundaries, on one timeline. Low
    // per-tenant quota keeps the device oversubscribed the way a dense
    // churn fleet is. Target: single-digit seconds.
    println!("\nLarge-horizon stress (event-queue core):");
    let mut stress_rows = Vec::new();
    for (tenants, rate_hz, duration_ms) in [(1000u32, 10.0f64, 100_000u64), (2000, 10.0, 100_000)]
    {
        let spec = ScenarioSpec::uniform_load("bench-uniform", tenants, rate_hz, 1, duration_ms, 1_000);
        let mut cfg = RunConfig::quick("native");
        cfg.seed = task_seed(
            dynamics_seed(42, spec.name, duration_ms, 1_000),
            "native",
            spec.name,
        );
        let t0 = Instant::now();
        let run = engine::run_scenario(&cfg, &spec);
        let dt = t0.elapsed().as_secs_f64();
        let eps = run.occurrences as f64 / dt.max(1e-9);
        stress_rows.push(vec![
            tenants.to_string(),
            format!("{:.0}s @ {} Hz", duration_ms as f64 / 1e3, rate_hz),
            run.occurrences.to_string(),
            run.completed.to_string(),
            format!("{dt:.2}"),
            format!("{eps:.0}"),
        ]);
        assert!(
            run.occurrences >= 1_000_000 || tenants < 1000,
            "stress run processed only {} occurrences",
            run.occurrences
        );
    }
    print_table(
        "Large-horizon stress — uniform load, single timeline",
        &["tenants", "horizon", "occurrences", "completed", "wall s", "events/s"],
        &stress_rows,
    );
}
