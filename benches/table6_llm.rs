//! Regenerates paper Table 6: LLM Metrics (relative to native, synthetic
//! workloads) for HAMi and FCSP, and — when `make artifacts` has run —
//! validates the real three-layer path by timing the PJRT-executed
//! JAX/Pallas attention under each backend's calibrated pacing.
//!
//! Paper values: LLM-001 82.3/91.5 % · LLM-002 76.4/88.2 % ·
//! TTFT 45.2/28.7 ms · ITL 12.8/8.4 ms · LLM-003 0.78/0.89.

use std::time::{Duration, Instant};

use gvb::benchkit::print_table;
use gvb::metrics::{llm, RunConfig};
use gvb::runtime::Engine;

fn main() {
    let native = RunConfig::for_system("native");
    let hami = RunConfig::for_system("hami");
    let fcsp = RunConfig::for_system("fcsp");

    // Relative-to-native rows (paper's presentation).
    let n001 = llm::llm_001(&native).value;
    let h001 = llm::llm_001(&hami).value / n001 * 100.0;
    let f001 = llm::llm_001(&fcsp).value / n001 * 100.0;
    let n002 = llm::llm_002(&native).value;
    let h002 = llm::llm_002(&hami).value / n002 * 100.0;
    let f002 = llm::llm_002(&fcsp).value / n002 * 100.0;
    let h004 = llm::llm_004(&hami).value;
    let f004 = llm::llm_004(&fcsp).value;
    let h_itl = llm::llm_004_itl(&hami);
    let f_itl = llm::llm_004_itl(&fcsp);
    let h003 = llm::llm_003(&hami).value;
    let f003 = llm::llm_003(&fcsp).value;

    let rows = vec![
        vec!["LLM-001 (Attention, %)".into(), format!("{h001:.1}"), format!("{f001:.1}"), "82.3 / 91.5".into()],
        vec!["LLM-002 (KV Cache, %)".into(), format!("{h002:.1}"), format!("{f002:.1}"), "76.4 / 88.2".into()],
        vec!["LLM-004 (TTFT, ms)".into(), format!("{h004:.1}"), format!("{f004:.1}"), "45.2 / 28.7".into()],
        vec!["LLM-004 (ITL, ms)".into(), format!("{h_itl:.1}"), format!("{f_itl:.1}"), "12.8 / 8.4".into()],
        vec!["LLM-003 (Batch Scale)".into(), format!("{h003:.2}"), format!("{f003:.2}"), "0.78 / 0.89".into()],
    ];
    print_table(
        "Table 6 — LLM Metrics (relative to native, synthetic workloads)",
        &["Metric", "HAMi", "FCSP", "paper (H/F)"],
        &rows,
    );

    // Three-layer validation: real Pallas attention through PJRT with the
    // simulator-calibrated admission pacing per backend.
    match Engine::load_default() {
        Ok(engine) => {
            let inputs: Vec<Vec<f32>> = engine
                .spec("attention_fp32")
                .unwrap()
                .inputs
                .iter()
                .map(|t| (0..t.element_count()).map(|i| (i % 31) as f32 * 0.03).collect())
                .collect();
            println!("\nThree-layer check (real PJRT attention, 20 iters/backend):");
            // Warm the executable (first execution pays XLA:CPU setup).
            for _ in 0..3 {
                engine.execute_f32("attention_fp32", &inputs).unwrap();
            }
            let mut native_ms = 0.0;
            for sys in ["native", "hami", "fcsp"] {
                let cfg = RunConfig::quick(sys);
                let pace_us = gvb::metrics::overhead::oh_001(&cfg).value
                    + 2.0 * gvb::metrics::overhead::oh_002(&cfg).value;
                let t0 = Instant::now();
                for _ in 0..20 {
                    std::thread::sleep(Duration::from_nanos((pace_us * 1e3) as u64));
                    engine.execute_f32("attention_fp32", &inputs).unwrap();
                }
                let ms = t0.elapsed().as_secs_f64() * 1e3 / 20.0;
                if sys == "native" {
                    native_ms = ms;
                }
                println!(
                    "  {sys:<8} {ms:>7.2} ms/iter  ({:.1}% of native)",
                    native_ms / ms * 100.0
                );
            }
        }
        Err(_) => println!("\n(artifacts missing — run `make artifacts` for the PJRT check)"),
    }
}
