//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. Rate limiter: HAMi's fixed quantum vs +feedback (kp) vs FCSP's
//!    GCRA pacing — where does the IS-003 accuracy gap come from?
//! 2. Quantization: HAMi's NVML measurement granularity sweep.
//! 3. Scheduling: round-robin vs WFQ fairness under heterogeneous load.
//! 4. Hook resolution: per-call lookup vs cached pointer.

use gvb::benchkit::print_table;
use gvb::simgpu::GpuDevice;
use gvb::stats::jain_fairness;
use gvb::virt::hooks::HookTable;
use gvb::virt::rate_limiter::{AdaptiveBucket, HamiLimiter};
use gvb::virt::wfq::WfqScheduler;

/// Drive a HAMi-style limiter and return achieved utilization.
fn drive_hami(l: &mut HamiLimiter, kernel_ns: f64, sim_ns: f64) -> f64 {
    let (mut now, mut busy) = (0.0, 0.0);
    while now < sim_ns {
        let a = l.acquire(kernel_ns, now);
        now += a.wait_ns + a.overhead_ns + kernel_ns;
        busy += kernel_ns;
        l.on_complete(1.0, kernel_ns);
    }
    busy / now
}

fn drive_adaptive(l: &mut AdaptiveBucket, kernel_ns: f64, sim_ns: f64) -> f64 {
    let (mut now, mut busy) = (0.0, 0.0);
    while now < sim_ns {
        let a = l.acquire(kernel_ns, now);
        now += a.wait_ns + a.overhead_ns + kernel_ns;
        busy += kernel_ns;
        l.on_complete(1.0, kernel_ns, now);
    }
    busy / now
}

fn ablation_rate_limiter() {
    let mut rows = Vec::new();
    for limit in [0.3, 0.5, 0.7] {
        let mut fixed = HamiLimiter::new(limit);
        let mut fb = HamiLimiter::new(limit);
        fb.set_kp(0.0); // ablate the feedback entirely
        let mut fine = HamiLimiter::new(limit);
        fine.set_quant(0.0); // ablate measurement quantization
        let mut gcra = AdaptiveBucket::new(limit);
        let k = 7e6;
        let t = 5e9;
        let err = |a: f64| (a - limit).abs() / limit * 100.0;
        rows.push(vec![
            format!("{limit:.1}"),
            format!("{:.1}%", err(drive_hami(&mut fixed, k, t))),
            format!("{:.1}%", err(drive_hami(&mut fb, k, t))),
            format!("{:.1}%", err(drive_hami(&mut fine, k, t))),
            format!("{:.1}%", err(drive_adaptive(&mut gcra, k, t))),
        ]);
    }
    print_table(
        "Ablation 1 — SM-limit error by limiter design (7 ms kernels)",
        &["target", "HAMi (kp=1,q=10%)", "kp=0", "no quant", "FCSP GCRA"],
        &rows,
    );
}

fn ablation_wfq() {
    // Heterogeneous tenants: kernel costs 7/2/3/5 (ms-scale units).
    let costs = [7.0, 2.0, 3.0, 5.0];
    // Round-robin: each turn serves one item per tenant → service time
    // proportional to cost.
    let rr: Vec<f64> = costs.iter().map(|c| c / costs.iter().sum::<f64>()).collect();
    // WFQ: virtual-time fair — equal service shares.
    let mut wfq = WfqScheduler::new();
    for t in 0..4u32 {
        wfq.add_tenant(t, 1.0);
    }
    let mut served = [0.0f64; 4];
    for _ in 0..4000 {
        let pending: Vec<(u32, f64)> = (0..4).map(|t| (t, costs[t as usize])).collect();
        let pick = wfq.pick(&pending).unwrap();
        let (t, c) = pending[pick];
        wfq.serve(t, c);
        served[t as usize] += c;
    }
    let total: f64 = served.iter().sum();
    let wfq_shares: Vec<f64> = served.iter().map(|s| s / total).collect();
    // IS-008's quantity: fairness of achieved *service* (device time /
    // FLOPs delivered) across tenants.
    print_table(
        "Ablation 2 — scheduling policy vs Jain fairness (heterogeneous kernels)",
        &["policy", "service shares", "Jain(service)"],
        &[
            vec![
                "round-robin (HAMi)".into(),
                format!("{rr:.2?}"),
                format!("{:.3}", jain_fairness(&rr)),
            ],
            vec![
                "WFQ (FCSP)".into(),
                format!("{wfq_shares:.2?}"),
                format!("{:.3}", jain_fairness(&wfq_shares)),
            ],
        ],
    );
}

fn ablation_hooks() {
    let mut dev = GpuDevice::a100(1);
    dev.spec.jitter_sigma = 0.0;
    let mut per_call = HookTable::hami();
    let mut cached = HookTable::fcsp();
    cached.call_ns(&mut dev); // warm
    let n = 10_000;
    let mut t_per_call = 0.0;
    let mut t_cached = 0.0;
    for _ in 0..n {
        t_per_call += per_call.call_ns(&mut dev);
        t_cached += cached.call_ns(&mut dev);
    }
    print_table(
        "Ablation 3 — dlsym hook resolution strategy (10k intercepted calls)",
        &["strategy", "mean ns/call", "total µs"],
        &[
            vec!["per-call lookup (HAMi)".into(), format!("{:.1}", t_per_call / n as f64), format!("{:.1}", t_per_call / 1e3)],
            vec!["cached pointer (FCSP)".into(), format!("{:.1}", t_cached / n as f64), format!("{:.1}", t_cached / 1e3)],
        ],
    );
}

fn main() {
    ablation_rate_limiter();
    ablation_wfq();
    ablation_hooks();
}
