//! Regenerates paper Table 7: Overall Benchmark Scores, running the full
//! 56-metric suite per system against the spec-derived MIG-Ideal baseline.
//!
//! Paper: MIG-Ideal 100 % A+ (by construction) · Native 100 % A+ (ceiling)
//! · BUD-FCSP 85.2 % B+ · HAMi-core 72.0 % C.
//!
//! Presentation matches the paper: MIG-Ideal is the baseline (100 % by
//! construction); Native is the performance ceiling and is not graded on
//! isolation (the paper reports it as A+ "true performance ceiling") — we
//! print both the paper-style row and our fully-scored value.

use gvb::benchkit::print_table;
use gvb::coordinator::SuiteRunner;
use gvb::metrics::{Category, RunConfig};

fn main() {
    let mut runner = SuiteRunner::new(RunConfig::for_system("native"));
    let mut rows = Vec::new();
    let mut details = Vec::new();
    for (sys, paper) in [("mig", "100% A+"), ("native", "100% A+ (ceiling)"), ("fcsp", "85.2% B+"), ("hami", "72.0% C")] {
        let suite = runner.run(sys);
        let pct = suite.card.mig_parity_percent();
        let grade = suite.card.grade().letter().to_string();
        rows.push(vec![
            sys.to_string(),
            format!("{pct:.1}%"),
            format!("{pct:.1}%"),
            grade,
            paper.to_string(),
        ]);
        details.push((sys.to_string(), suite));
    }
    print_table(
        "Table 7 — Overall Benchmark Scores (full 56-metric suite)",
        &["System", "Score", "MIG Parity", "Grade", "paper"],
        &rows,
    );
    println!("\nPer-category breakdown:");
    print!("{:<18}", "Category (weight)");
    for (sys, _) in &details {
        print!("{sys:>8}");
    }
    println!();
    for c in Category::ALL {
        print!("{:<18}", format!("{} ({:.2})", c.key(), c.weight()));
        for (_, suite) in &details {
            print!("{:>8.2}", suite.card.per_category.get(&c).copied().unwrap_or(f64::NAN));
        }
        println!();
    }
    println!("\nShape check vs paper §8: software reaches 70–85 % of MIG-Ideal;");
    println!("FCSP > HAMi across isolation and LLM categories; HAMi grades C.");
}
