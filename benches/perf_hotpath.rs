//! §Perf: wall-clock cost of the framework itself (not virtual time).
//!
//! Targets (DESIGN.md §9): < 200 ns/simulated API call on the hot path;
//! a full quick suite per system in seconds; PJRT wrapper overhead < 5 %
//! of execute time. Results are recorded in EXPERIMENTS.md §Perf.

use std::time::Instant;

use gvb::benchkit::{bench, print_table};
use gvb::cudalite::Api;
use gvb::metrics::RunConfig;
use gvb::simgpu::kernel::KernelDesc;
use gvb::virt::TenantConfig;

fn main() {
    let mut rows = Vec::new();

    // 1. Hot path: launch + sync through the full interposition stack.
    for sys in ["native", "hami", "fcsp", "mig"] {
        let mut api = Api::with_backend(sys, 42);
        api.ctx_create(1, TenantConfig::unlimited().with_mem_limit(20 << 30)).unwrap();
        let kernel = KernelDesc::null();
        let r = bench(&format!("launch+sync [{sys}]"), 2_000, 20_000, || {
            api.launch_kernel(1, 0, &kernel).unwrap();
            api.sync_stream(1, 0).unwrap();
        });
        rows.push(vec![r.name.clone(), format!("{:.0}", r.summary.mean), format!("{:.0}", r.summary.p99)]);
    }

    // 2. Alloc/free cycle (allocator + accounting wallclock).
    for sys in ["native", "hami"] {
        let mut api = Api::with_backend(sys, 43);
        api.ctx_create(1, TenantConfig::unlimited()).unwrap();
        let r = bench(&format!("alloc+free 1MiB [{sys}]"), 2_000, 20_000, || {
            let p = api.mem_alloc(1, 1 << 20).unwrap();
            api.mem_free(1, p).unwrap();
        });
        rows.push(vec![r.name.clone(), format!("{:.0}", r.summary.mean), format!("{:.0}", r.summary.p99)]);
    }

    // 3. L2 cache model access.
    {
        let mut dev = gvb::simgpu::GpuDevice::a100(44);
        let mut addr = 0u64;
        let r = bench("l2.access", 10_000, 100_000, || {
            dev.l2.access(1, addr);
            addr = addr.wrapping_add(128);
        });
        rows.push(vec![r.name.clone(), format!("{:.0}", r.summary.mean), format!("{:.0}", r.summary.p99)]);
    }

    print_table("§Perf — wall-clock hot paths", &["path", "mean ns", "p99 ns"], &rows);

    // 4. Whole quick suite wallclock per system.
    println!("\nFull 56-metric quick suite wallclock:");
    for sys in ["native", "hami", "fcsp", "mig"] {
        let t0 = Instant::now();
        let results = gvb::metrics::registry::run_all(&RunConfig::quick(sys));
        println!("  {sys:<8} {:>6.2} s ({} metrics)", t0.elapsed().as_secs_f64(), results.len());
    }

    // 5. PJRT wrapper overhead: execute vs execute+wrapper bookkeeping.
    match gvb::runtime::Engine::load_default() {
        Ok(engine) => {
            let inputs: Vec<Vec<f32>> = engine
                .spec("attention_small_fp32")
                .unwrap()
                .inputs
                .iter()
                .map(|t| vec![0.1f32; t.element_count()])
                .collect();
            let r = bench("pjrt attention_small", 3, 30, || {
                engine.execute_f32("attention_small_fp32", &inputs).unwrap();
            });
            println!(
                "\nPJRT execute (attention_small_fp32): mean {:.2} ms, p99 {:.2} ms",
                r.summary.mean / 1e6,
                r.summary.p99 / 1e6
            );
        }
        Err(_) => println!("\n(artifacts missing — skipping PJRT timing)"),
    }
}
