//! Cluster-grid scaling: wall-clock of a (systems × policies × nodes ×
//! scenarios) fleet-replay grid at 1 → N executor workers, plus a
//! bit-identity spot check between the serial and widest runs.
//!
//! The nodes axis sweeps 10 → 100 so the per-task cost spread is real:
//! a 100-node replay scans an order of magnitude more nodes per
//! placement than a 10-node one, which exercises the executor's load
//! balance on heterogeneous task weights.

use std::time::Instant;

use gvb::benchkit::print_table;
use gvb::cluster::{run_cluster, ClusterSpec, POLICIES};
use gvb::dynsim::PRESETS;
use gvb::metrics::RunConfig;
use gvb::report::cluster::render_summary_csv;
use gvb::virt::ALL_SYSTEMS;

fn main() {
    let base = RunConfig::quick("native");
    let spec = ClusterSpec {
        systems: ALL_SYSTEMS.iter().map(|s| s.to_string()).collect(),
        policies: POLICIES.to_vec(),
        node_counts: vec![10, 100],
        scenarios: PRESETS.to_vec(),
        arrivals: 2000,
    };
    let cells = spec.systems.len()
        * spec.policies.len()
        * spec.node_counts.len()
        * spec.scenarios.len();
    println!(
        "Cluster grid: {} systems x {} policies x {:?} nodes x {} scenarios = {} fleet replays ({} arrivals each)",
        spec.systems.len(),
        spec.policies.len(),
        spec.node_counts,
        spec.scenarios.len(),
        cells,
        spec.arrivals
    );

    let hw = gvb::coordinator::executor::resolve_jobs(0);
    let mut job_counts = vec![1usize, 2, 4];
    if hw > 4 {
        job_counts.push(hw);
    }
    job_counts.dedup();

    let mut rows = Vec::new();
    let mut serial_s = 0.0;
    let mut serial_summary = String::new();
    for &jobs in &job_counts {
        let t0 = Instant::now();
        let surface = run_cluster(&base, &spec, jobs);
        let dt = t0.elapsed().as_secs_f64();
        let summary = render_summary_csv(&surface);
        if jobs == 1 {
            serial_s = dt;
            serial_summary = summary;
        } else {
            assert_eq!(summary, serial_summary, "determinism violated at jobs={jobs}");
        }
        let placed: u32 = surface.runs.iter().map(|r| r.placed).sum();
        rows.push(vec![
            jobs.to_string(),
            format!("{dt:.2}"),
            format!("{:.2}x", serial_s / dt),
            format!("{:.2}x", surface.stats.speedup_estimate()),
            format!("{:.0} ms", surface.stats.max_task_ns() as f64 / 1e6),
            placed.to_string(),
        ]);
    }
    print_table(
        "Cluster scaling — 5 systems x 3 policies x {10,100} nodes x 4 scenarios",
        &["jobs", "wall s", "speedup vs 1", "busy/wall", "longest replay", "placed"],
        &rows,
    );
    println!("\n(host parallelism: {hw}; summary CSV verified byte-identical across job counts)");
}
