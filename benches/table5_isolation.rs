//! Regenerates paper Table 5: Isolation Metrics (4 concurrent tenants)
//! for HAMi and FCSP.
//!
//! Paper values: IS-001 98.2/99.1 % · IS-003 85.4/92.7 % · IS-005 Pass ·
//! IS-008 0.87/0.94 · IS-009 24.3/12.1 % · IS-010 Pass.

use gvb::benchkit::print_table;
use gvb::metrics::{isolation, MetricResult, RunConfig};

fn fmt(r: &MetricResult) -> String {
    match r.pass {
        Some(true) => "Pass".to_string(),
        Some(false) => "FAIL".to_string(),
        None => format!("{:.2}", r.value),
    }
}

fn main() {
    let rows_def: [(&str, fn(&RunConfig) -> MetricResult, &str); 6] = [
        ("IS-001 (Mem Accuracy, %)", isolation::is_001, "98.2 / 99.1"),
        ("IS-003 (SM Accuracy, %)", isolation::is_003, "85.4 / 92.7"),
        ("IS-005 (Mem Isolation)", isolation::is_005, "Pass / Pass"),
        ("IS-008 (Fairness Index)", isolation::is_008, "0.87 / 0.94"),
        ("IS-009 (Noisy Neighbor, %)", isolation::is_009, "24.3 / 12.1"),
        ("IS-010 (Fault Isolation)", isolation::is_010, "Pass / Pass"),
    ];
    let mut rows = Vec::new();
    for (name, f, paper) in rows_def {
        let h = f(&RunConfig::for_system("hami"));
        let fc = f(&RunConfig::for_system("fcsp"));
        rows.push(vec![name.to_string(), fmt(&h), fmt(&fc), paper.to_string()]);
    }
    print_table(
        "Table 5 — Isolation Metrics (4 concurrent tenants)",
        &["Metric", "HAMi", "FCSP", "paper (H/F)"],
        &rows,
    );
    println!("\nKey findings (paper §7.4): both systems achieve memory isolation;");
    println!("SM utilization control is approximate; FCSP is fairer under contention.");
}
