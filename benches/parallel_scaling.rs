//! Parallel executor scaling: wall-clock of the full 4-system × 56-metric
//! matrix (224 tasks) at 1 → N workers, plus a bit-identity spot check
//! between the serial and widest runs.
//!
//! Acceptance target: > 1.5× wall-clock speedup at 4 workers vs 1 on a
//! ≥ 4-core host (the tasks are independent CPU-bound simulations, so
//! scaling is limited only by the longest single metric).

use std::time::Instant;

use gvb::benchkit::print_table;
use gvb::coordinator::executor::{self, Task};
use gvb::metrics::{taxonomy, RunConfig};
use gvb::virt::ALL_SYSTEMS;

fn main() {
    let base = RunConfig::quick("native");
    let ids: Vec<&'static str> = taxonomy::ALL.iter().map(|d| d.id).collect();
    let tasks: Vec<Task> = executor::task_matrix(&ALL_SYSTEMS, &ids);
    println!(
        "Full matrix: {} systems x {} metrics = {} tasks (quick config)",
        ALL_SYSTEMS.len(),
        ids.len(),
        tasks.len()
    );

    let hw = executor::resolve_jobs(0);
    let mut job_counts = vec![1usize, 2, 4];
    if hw > 4 {
        job_counts.push(hw);
    }
    job_counts.dedup();

    let mut rows = Vec::new();
    let mut serial_s = 0.0;
    let mut serial_values: Vec<u64> = Vec::new();
    for &jobs in &job_counts {
        let t0 = Instant::now();
        let (results, stats) = executor::execute(&base, &tasks, jobs);
        let dt = t0.elapsed().as_secs_f64();
        let values: Vec<u64> = results.iter().map(|r| r.value.to_bits()).collect();
        if jobs == 1 {
            serial_s = dt;
            serial_values = values;
        } else {
            assert_eq!(values, serial_values, "determinism violated at jobs={jobs}");
        }
        rows.push(vec![
            jobs.to_string(),
            format!("{dt:.2}"),
            format!("{:.2}x", serial_s / dt),
            format!("{:.2}x", stats.speedup_estimate()),
            format!("{:.0} ms", stats.max_task_ns() as f64 / 1e6),
        ]);
    }
    print_table(
        "Parallel executor scaling — 4 systems x 56 metrics",
        &["jobs", "wall s", "speedup vs 1", "busy/wall", "longest task"],
        &rows,
    );
    println!("\n(host parallelism: {hw}; results verified bit-identical across job counts)");
}
