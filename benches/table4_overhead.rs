//! Regenerates paper Table 4: Overhead Metrics Comparison (µs unless
//! noted) for Native / HAMi / FCSP, plus the paper's key findings.
//!
//! Paper values for reference:
//!   OH-001 4.2 / 15.3 / 8.7 · OH-002 12.5 / 45.2 / 28.3
//!   OH-003 8.1 / 32.4 / 18.6 · OH-004 125 / 312 / 198
//!   OH-005 — / 85 / 42 ns · OH-010 0 / 18.5 / 9.2 %

use gvb::benchkit::print_table;
use gvb::metrics::{overhead, RunConfig};

fn main() {
    let systems = ["native", "hami", "fcsp"];
    let metrics: [(&str, fn(&RunConfig) -> gvb::metrics::MetricResult, &str, [f64; 3]); 6] = [
        ("OH-001 (Launch)", overhead::oh_001, "µs", [4.2, 15.3, 8.7]),
        ("OH-002 (Alloc)", overhead::oh_002, "µs", [12.5, 45.2, 28.3]),
        ("OH-003 (Free)", overhead::oh_003, "µs", [8.1, 32.4, 18.6]),
        ("OH-004 (Context)", overhead::oh_004, "µs", [125.0, 312.0, 198.0]),
        ("OH-005 (Hook, ns)", overhead::oh_005, "ns", [0.0, 85.0, 42.0]),
        ("OH-010 (Degrade, %)", overhead::oh_010, "%", [0.0, 18.5, 9.2]),
    ];
    let mut rows = Vec::new();
    let mut measured = vec![[0.0f64; 3]; metrics.len()];
    for (mi, (name, f, _unit, paper)) in metrics.iter().enumerate() {
        let mut row = vec![name.to_string()];
        for (si, sys) in systems.iter().enumerate() {
            let v = f(&RunConfig::for_system(sys)).value;
            measured[mi][si] = v;
            row.push(format!("{v:.1}"));
        }
        row.push(format!("{:.1} / {:.1} / {:.1}", paper[0], paper[1], paper[2]));
        rows.push(row);
    }
    print_table(
        "Table 4 — Overhead Metrics Comparison (simulated A100-40GB)",
        &["Metric", "Native", "HAMi", "FCSP", "paper (N/H/F)"],
        &rows,
    );
    // Key findings (paper §7.3) — recomputed from measurements.
    let launch_ratio = measured[0][1] / measured[0][0];
    let fcsp_vs_hami =
        (measured[0][1] - measured[0][2]) / (measured[0][1] - measured[0][0]) * 100.0;
    println!("\nKey findings (recomputed):");
    println!("  HAMi-core adds {launch_ratio:.1}x kernel launch overhead (paper: 3.6x)");
    println!("  BUD-FCSP reduces added launch overhead by {fcsp_vs_hami:.0}% vs HAMi (paper: ~60% of the added cost; 43% of total)");
    println!(
        "  Memory ops show the highest relative impact: alloc {:.1}x (paper 3.6x)",
        measured[1][1] / measured[1][0]
    );
}
